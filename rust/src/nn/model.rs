//! Rust-native quantized CNN forward — the mirror of
//! `python/compile/model.py` (same architecture, same static quantization,
//! same LUT-routed multiplies). Used to cross-check the AOT JAX graph and
//! as a fallback evaluator when PJRT artifacts are absent.
//!
//! The batched forward is built from **resumable stages** split at layer
//! boundaries: [`QuantCnn::input_checkpoint`] →
//! [`QuantCnn::advance_checkpoint`]* → [`QuantCnn::finish_checkpoint`],
//! with [`BatchCheckpoint`] carrying the quantized (and im2col'd) GEMM
//! input between stages. `forward_batch_hetero` is exactly that stage
//! chain, so replaying a suffix from a cached checkpoint is bit-identical
//! to the full forward by construction — the basis of the compile
//! search's incremental evaluation (`DESIGN.md` §Compile pass), together
//! with [`QuantCnn::reference_chain`] / [`QuantCnn::delta_resume_exact`]
//! (sparse linear delta replay against a pinned all-exact baseline).
//!
//! All batched GEMMs run through [`super::quant::lut_matmul_batched`],
//! whose inner strips dispatch at runtime through [`crate::util::simd`]
//! (AVX2 / NEON / scalar, bit-identical outputs — `DESIGN.md` §"SIMD
//! kernels"), so every forward here inherits the vectorized kernels
//! without caring which level the host runs.
//!
//! Architecture (16×16×1 input, 10 classes):
//!   conv3x3(1→8) + relu + maxpool2 → conv3x3(8→16) + relu + maxpool2
//!   → flatten(2·2·16=64)… wait: 16→14→7→5→2 — flatten 2×2×16 = 64
//!   → fc(64→32) + relu → fc(32→10).

use anyhow::{bail, Context, Result};
use std::path::Path;

use super::quant::{lut_matmul, lut_matmul_acc, quantize, quantize_all};
use crate::util::npy;
use crate::util::threadpool::parallel_map;

/// One quantized layer: int8 weights + scales.
#[derive(Clone, Debug)]
pub struct QuantLayer {
    /// Quantized weights, layout documented per use.
    pub w_q: Vec<i8>,
    pub w_scale: f32,
    /// Input activation scale (calibrated).
    pub in_scale: f32,
    /// float bias.
    pub bias: Vec<f32>,
}

/// The full quantized CNN.
#[derive(Clone, Debug)]
pub struct QuantCnn {
    /// conv1: [out=8, in=1, 3, 3] flattened as (9) × 8 matrix after im2col.
    pub conv1: QuantLayer,
    /// conv2: [out=16, in=8, 3, 3] → (72) × 16.
    pub conv2: QuantLayer,
    /// fc1: 64 × 32.
    pub fc1: QuantLayer,
    /// fc2: 32 × 10.
    pub fc2: QuantLayer,
}

pub const IMG: usize = 16;
pub const C1_OUT: usize = 8;
pub const C2_OUT: usize = 16;
pub const FC1_OUT: usize = 32;
pub const CLASSES: usize = 10;

/// Number of LUT-routed layers in the network.
pub const N_LAYERS: usize = 4;
/// Canonical layer names, in forward order — the index space shared by
/// [`LayerLuts`], the compile pass and every plan artifact.
pub const LAYER_NAMES: [&str; N_LAYERS] = ["conv1", "conv2", "fc1", "fc2"];

/// One int8-product LUT per layer — the heterogeneous-multiplier view of
/// the network. Every forward path dispatches each layer's multiplies
/// through its own LUT; the historical single-LUT entry points are the
/// uniform special case ([`LayerLuts::uniform`]), so a uniform assignment
/// is *definitionally* bit-identical to the single-LUT path.
#[derive(Clone, Copy, Debug)]
pub struct LayerLuts<'a> {
    pub conv1: &'a [i32],
    pub conv2: &'a [i32],
    pub fc1: &'a [i32],
    pub fc2: &'a [i32],
}

impl<'a> LayerLuts<'a> {
    /// The same LUT on every layer (the classic homogeneous configuration).
    pub fn uniform(lut: &'a [i32]) -> LayerLuts<'a> {
        LayerLuts {
            conv1: lut,
            conv2: lut,
            fc1: lut,
            fc2: lut,
        }
    }

    /// The LUT of layer `l`, in [`LAYER_NAMES`] order.
    pub fn get(&self, l: usize) -> &'a [i32] {
        match l {
            0 => self.conv1,
            1 => self.conv2,
            2 => self.fc1,
            3 => self.fc2,
            _ => panic!("layer index {l} out of range"),
        }
    }
}

/// Per-layer GEMM geometry `(rows per image, reduction depth k, outputs n)`
/// in [`LAYER_NAMES`] order, fixed by the architecture: conv layers run one
/// GEMM row per im2col patch, fc layers one row per image. The product
/// `rows · k · n` equals [`layer_macs_per_image`] per layer.
pub const LAYER_GEMM: [(usize, usize, usize); N_LAYERS] = [
    ((IMG - 2) * (IMG - 2), 9, C1_OUT), // conv1: 14·14 patches × 3·3·1 taps
    (5 * 5, 9 * C1_OUT, C2_OUT),        // conv2: 5·5 patches × 3·3·8 taps
    (1, 2 * 2 * C2_OUT, FC1_OUT),       // fc1: 64 → 32
    (1, FC1_OUT, CLASSES),              // fc2: 32 → 10
];

/// Multiply–accumulate count per image per layer, in [`LAYER_NAMES`]
/// order — the weights the compile pass uses to turn per-multiplier
/// energy into per-layer (and per-image) energy estimates. Derived from
/// the fixed architecture: conv layers count im2col-rows × k × out,
/// fc layers in × out.
pub fn layer_macs_per_image() -> [u64; N_LAYERS] {
    let c1h = IMG - 2; // 3x3 valid conv
    let conv1 = (c1h * c1h * 9 * C1_OUT) as u64;
    let p1 = c1h / 2; // maxpool2
    let c2h = p1 - 2;
    let conv2 = (c2h * c2h * 9 * C1_OUT * C2_OUT) as u64;
    let p2 = c2h / 2;
    let flat = p2 * p2 * C2_OUT;
    let fc1 = (flat * FC1_OUT) as u64;
    let fc2 = (FC1_OUT * CLASSES) as u64;
    [conv1, conv2, fc1, fc2]
}

/// The quantized GEMM input of one layer for a whole image batch — the
/// unit of the compile search's prefix checkpointing. A checkpoint at
/// `layer == l` captures everything the forward needs to resume at layer
/// `l`: the batch-stacked, already-quantized (and, for conv layers,
/// already-im2col'd) activation matrix. It depends only on the LUTs of
/// layers `0..l` — quantization is a pure per-element map and im2col a
/// pure copy of activations, neither reads a LUT — so the matrix is
/// reusable across every assignment sharing that LUT prefix.
#[derive(Clone, Debug)]
pub struct BatchCheckpoint {
    /// Next layer to execute (index into [`LAYER_NAMES`]).
    layer: usize,
    /// Images in the batch.
    bsz: usize,
    /// Quantized GEMM input: `bsz · rows_per_image` rows of `k` i8 each
    /// (geometry per [`LAYER_GEMM`]).
    a_q: Vec<i8>,
}

impl BatchCheckpoint {
    /// Next layer to execute.
    pub fn layer(&self) -> usize {
        self.layer
    }

    /// Images in the batch.
    pub fn batch(&self) -> usize {
        self.bsz
    }
}

/// A fully expanded forward of one assignment: per-layer checkpoints, raw
/// i64 GEMM accumulators, and the final logits. Built by
/// [`QuantCnn::reference_chain`]; consumed as the pinned baseline of the
/// compile search's incremental evaluator.
pub struct ReferenceChain {
    ckpts: Vec<BatchCheckpoint>,
    accs: Vec<Vec<i64>>,
    logits: Vec<Vec<f32>>,
}

impl ReferenceChain {
    /// The checkpoint at `depth` (input to layer `depth`).
    pub fn checkpoint(&self, depth: usize) -> &BatchCheckpoint {
        &self.ckpts[depth]
    }

    /// Per-image logits of the anchored assignment.
    pub fn logits(&self) -> &[Vec<f32>] {
        &self.logits
    }
}

fn im2col_gen<T: Copy>(
    input: &[T],
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    zero: T,
) -> (Vec<T>, usize, usize) {
    // input layout HWC; output rows = (h-k+1)*(w-k+1), cols = k*k*c
    let oh = h - k + 1;
    let ow = w - k + 1;
    let cols = k * k * c;
    let mut out = vec![zero; oh * ow * cols];
    for oy in 0..oh {
        for ox in 0..ow {
            let row = oy * ow + ox;
            let mut idx = 0;
            for ky in 0..k {
                for kx in 0..k {
                    for ch in 0..c {
                        out[row * cols + idx] = input[((oy + ky) * w + (ox + kx)) * c + ch];
                        idx += 1;
                    }
                }
            }
        }
    }
    (out, oh * ow, cols)
}

fn im2col(input: &[f32], h: usize, w: usize, c: usize, k: usize) -> (Vec<f32>, usize, usize) {
    im2col_gen(input, h, w, c, k, 0f32)
}

/// Batch-of-N im2col over *already quantized* activations: images are
/// stacked along the row axis, so one GEMM covers the whole batch and
/// every weight tile is reused `N` times. Operating on i8 after
/// quantization is bit-equivalent to the scalar path's quantize-after-
/// im2col (im2col only copies elements, and quantization is a pure
/// per-element map), but quantizes each activation once instead of once
/// per patch it appears in (~k·k times).
/// Returns (matrix, rows per image, cols); total rows = `batch * rows`.
fn im2col_batch_i8(
    input: &[i8],
    batch: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
) -> (Vec<i8>, usize, usize) {
    let per_image = h * w * c;
    assert_eq!(input.len(), batch * per_image);
    let oh = h - k + 1;
    let ow = w - k + 1;
    let cols = k * k * c;
    let mut out = Vec::with_capacity(batch * oh * ow * cols);
    let mut rows = oh * ow;
    for i in 0..batch {
        let (one, m, _) = im2col_gen(&input[i * per_image..(i + 1) * per_image], h, w, c, k, 0i8);
        rows = m;
        out.extend_from_slice(&one);
    }
    (out, rows, cols)
}

fn relu(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        if *x < 0.0 {
            *x = 0.0;
        }
    }
}

fn maxpool2(input: &[f32], h: usize, w: usize, c: usize) -> (Vec<f32>, usize, usize) {
    let oh = h / 2;
    let ow = w / 2;
    let mut out = vec![f32::MIN; oh * ow * c];
    for y in 0..oh {
        for x in 0..ow {
            for ch in 0..c {
                let mut m = f32::MIN;
                for dy in 0..2 {
                    for dx in 0..2 {
                        m = m.max(input[((2 * y + dy) * w + (2 * x + dx)) * c + ch]);
                    }
                }
                out[(y * ow + x) * c + ch] = m;
            }
        }
    }
    (out, oh, ow)
}

impl QuantCnn {
    /// Quantized conv/fc as im2col + LUT matmul + bias.
    fn layer_forward(
        &self,
        lut: &[i32],
        layer: &QuantLayer,
        input: &[f32],
        m: usize,
        k: usize,
        n: usize,
    ) -> Vec<f32> {
        let a_q = quantize_all(input, layer.in_scale);
        let mut out = lut_matmul(lut, &a_q, &layer.w_q, m, k, n, layer.in_scale, layer.w_scale);
        for row in 0..m {
            for j in 0..n {
                out[row * n + j] += layer.bias[j];
            }
        }
        out
    }

    /// Forward one image (u8 16×16 grayscale) → 10 logits.
    pub fn forward(&self, lut: &[i32], image: &[u8]) -> Vec<f32> {
        self.forward_hetero(&LayerLuts::uniform(lut), image)
    }

    /// [`QuantCnn::forward`] with a per-layer LUT assignment: each layer's
    /// multiplies go through its own LUT. With [`LayerLuts::uniform`] this
    /// *is* `forward` (same code path).
    pub fn forward_hetero(&self, luts: &LayerLuts, image: &[u8]) -> Vec<f32> {
        assert_eq!(image.len(), IMG * IMG);
        // Normalize to [0,1].
        let x: Vec<f32> = image.iter().map(|&p| p as f32 / 255.0).collect();
        // conv1
        let (cols, m, k) = im2col(&x, IMG, IMG, 1, 3);
        let mut h1 = self.layer_forward(luts.conv1, &self.conv1, &cols, m, k, C1_OUT);
        relu(&mut h1);
        let (p1, h1h, h1w) = maxpool2(&h1, IMG - 2, IMG - 2, C1_OUT);
        // conv2
        let (cols2, m2, k2) = im2col(&p1, h1h, h1w, C1_OUT, 3);
        let mut h2 = self.layer_forward(luts.conv2, &self.conv2, &cols2, m2, k2, C2_OUT);
        relu(&mut h2);
        let (p2, p2h, p2w) = maxpool2(&h2, h1h - 2, h1w - 2, C2_OUT);
        // flatten → fc1 → fc2
        let flat_len = p2h * p2w * C2_OUT;
        let mut h3 = self.layer_forward(luts.fc1, &self.fc1, &p2, 1, flat_len, FC1_OUT);
        relu(&mut h3);
        self.layer_forward(luts.fc2, &self.fc2, &h3, 1, FC1_OUT, CLASSES)
    }

    /// The layer struct at index `l` ([`LAYER_NAMES`] order).
    fn layer_at(&self, l: usize) -> &QuantLayer {
        match l {
            0 => &self.conv1,
            1 => &self.conv2,
            2 => &self.fc1,
            _ => &self.fc2,
        }
    }

    /// Build the depth-0 checkpoint: normalize + quantize the whole batch
    /// once, BEFORE im2col (im2col only copies elements and quantization
    /// is a pure per-element map, so quantize∘im2col == im2col∘quantize —
    /// but this way each activation quantizes once, not once per patch),
    /// then im2col for conv1. Depends only on the images, so every
    /// per-layer LUT assignment shares it.
    pub fn input_checkpoint(&self, images: &[&[u8]]) -> BatchCheckpoint {
        let bsz = images.len();
        let mut xq = Vec::with_capacity(bsz * IMG * IMG);
        for img in images {
            assert_eq!(img.len(), IMG * IMG);
            xq.extend(
                img.iter()
                    .map(|&p| quantize(p as f32 / 255.0, self.conv1.in_scale)),
            );
        }
        let (a1, _, _) = im2col_batch_i8(&xq, bsz, IMG, IMG, 1, 3);
        BatchCheckpoint {
            layer: 0,
            bsz,
            a_q: a1,
        }
    }

    /// Raw i64 GEMM accumulators of the checkpoint's layer through `lut`
    /// (blocked kernel, row-tiles across the thread pool).
    fn checkpoint_acc(&self, ck: &BatchCheckpoint, lut: &[i32], threads: usize) -> Vec<i64> {
        let (rows, k, n) = LAYER_GEMM[ck.layer];
        lut_matmul_acc(
            lut,
            &ck.a_q,
            &self.layer_at(ck.layer).w_q,
            ck.bsz * rows,
            k,
            n,
            threads,
        )
    }

    /// The f32 post-GEMM pipeline of layer `l` from its raw accumulators:
    /// dequantize, bias, relu, maxpool (conv layers), quantize for the
    /// next layer, im2col — exactly the op sequence (and order) the
    /// monolithic forward ran, so stage-by-stage execution is
    /// bit-identical to it by construction.
    fn post_ops_checkpoint(&self, l: usize, bsz: usize, acc: &[i64]) -> BatchCheckpoint {
        let layer = self.layer_at(l);
        let (_, _, n) = LAYER_GEMM[l];
        let s = layer.in_scale * layer.w_scale;
        let mut h: Vec<f32> = Vec::with_capacity(acc.len());
        for row in acc.chunks_exact(n) {
            for (&v, &bias) in row.iter().zip(&layer.bias) {
                h.push(v as f32 * s + bias);
            }
        }
        relu(&mut h);
        match l {
            0 => {
                let side = IMG - 2; // 14×14 conv1 output
                let per = side * side * C1_OUT;
                let mut pooled = Vec::with_capacity(bsz * per / 4);
                let (mut ph, mut pw) = (1, 1);
                for i in 0..bsz {
                    let (p, hh, ww) = maxpool2(&h[i * per..(i + 1) * per], side, side, C1_OUT);
                    ph = hh;
                    pw = ww;
                    pooled.extend_from_slice(&p);
                }
                let pq = quantize_all(&pooled, self.conv2.in_scale);
                let (a2, _, _) = im2col_batch_i8(&pq, bsz, ph, pw, C1_OUT, 3);
                BatchCheckpoint {
                    layer: 1,
                    bsz,
                    a_q: a2,
                }
            }
            1 => {
                let side = (IMG - 2) / 2 - 2; // 5×5 conv2 output
                let per = side * side * C2_OUT;
                let mut pooled = Vec::with_capacity(bsz * per / 4);
                for i in 0..bsz {
                    let (p, _, _) = maxpool2(&h[i * per..(i + 1) * per], side, side, C2_OUT);
                    pooled.extend_from_slice(&p);
                }
                let pq = quantize_all(&pooled, self.fc1.in_scale);
                BatchCheckpoint {
                    layer: 2,
                    bsz,
                    a_q: pq,
                }
            }
            2 => {
                let hq = quantize_all(&h, self.fc2.in_scale);
                BatchCheckpoint {
                    layer: 3,
                    bsz,
                    a_q: hq,
                }
            }
            _ => unreachable!("fc2 has no successor checkpoint"),
        }
    }

    /// Execute the checkpoint's layer through `lut` and return the next
    /// layer's checkpoint. Panics on the last layer — use
    /// [`QuantCnn::finish_checkpoint`] there.
    pub fn advance_checkpoint(
        &self,
        ck: &BatchCheckpoint,
        lut: &[i32],
        threads: usize,
    ) -> BatchCheckpoint {
        assert!(
            ck.layer < N_LAYERS - 1,
            "cannot advance past fc1: use finish_checkpoint"
        );
        let acc = self.checkpoint_acc(ck, lut, threads);
        self.post_ops_checkpoint(ck.layer, ck.bsz, &acc)
    }

    /// Execute the final layer from its checkpoint: per-image logits.
    pub fn finish_checkpoint(
        &self,
        ck: &BatchCheckpoint,
        lut: &[i32],
        threads: usize,
    ) -> Vec<Vec<f32>> {
        assert_eq!(ck.layer, N_LAYERS - 1, "finish needs the fc2 checkpoint");
        let acc = self.checkpoint_acc(ck, lut, threads);
        self.logits_from_acc(&acc, ck.bsz)
    }

    fn logits_from_acc(&self, acc: &[i64], bsz: usize) -> Vec<Vec<f32>> {
        let layer = &self.fc2;
        let s = layer.in_scale * layer.w_scale;
        (0..bsz)
            .map(|i| {
                (0..CLASSES)
                    .map(|j| acc[i * CLASSES + j] as f32 * s + layer.bias[j])
                    .collect()
            })
            .collect()
    }

    /// Resume the forward from `ck`: run layers `ck.layer()..` under
    /// `luts`. Bit-identical to the tail of a full
    /// [`QuantCnn::forward_batch_hetero`] for any checkpoint produced by
    /// [`QuantCnn::input_checkpoint`] + [`QuantCnn::advance_checkpoint`]
    /// under the same prefix LUTs — the stages *are* the full forward
    /// ([`QuantCnn::forward_batch_hetero`] is input_checkpoint + resume).
    pub fn resume_batch_hetero(
        &self,
        ck: &BatchCheckpoint,
        luts: &LayerLuts,
        threads: usize,
    ) -> Vec<Vec<f32>> {
        if ck.layer == N_LAYERS - 1 {
            return self.finish_checkpoint(ck, luts.get(N_LAYERS - 1), threads);
        }
        let mut cur = self.advance_checkpoint(ck, luts.get(ck.layer), threads);
        while cur.layer < N_LAYERS - 1 {
            cur = self.advance_checkpoint(&cur, luts.get(cur.layer), threads);
        }
        self.finish_checkpoint(&cur, luts.get(N_LAYERS - 1), threads)
    }

    /// A fully expanded forward of one assignment: every layer's
    /// checkpoint plus its raw i64 GEMM accumulators and the final
    /// logits. The compile search pins one of these for the all-exact
    /// baseline: the checkpoints serve as pinned replay prefixes, the
    /// accumulators anchor [`QuantCnn::delta_resume_exact`].
    pub fn reference_chain(
        &self,
        luts: &LayerLuts,
        images: &[&[u8]],
        threads: usize,
    ) -> ReferenceChain {
        let bsz = images.len();
        let mut ckpts = vec![self.input_checkpoint(images)];
        let mut accs = Vec::with_capacity(N_LAYERS);
        for l in 0..N_LAYERS {
            let acc = self.checkpoint_acc(&ckpts[l], luts.get(l), threads);
            if l < N_LAYERS - 1 {
                ckpts.push(self.post_ops_checkpoint(l, bsz, &acc));
            }
            accs.push(acc);
        }
        let logits = self.logits_from_acc(&accs[N_LAYERS - 1], bsz);
        ReferenceChain {
            ckpts,
            accs,
            logits,
        }
    }

    /// Replay layers `ck.layer()..` against `anchor`, where both the
    /// anchor and the assignment run the **exact** multiplier on every
    /// remaining layer (caller-guaranteed precondition). The exact int8
    /// LUT is linear (`lut[a][w] == a·w`), so each layer's accumulators
    /// are reconstructed as `acc' = acc₀ + Σ_changed (a' − a₀)·w` — exact
    /// integer arithmetic, hence bit-identical to a full replay (integer
    /// sums are order-independent and the f32 post-ops re-run per element
    /// exactly as in the full path), at a cost proportional to the
    /// *changed* activation entries instead of the whole GEMM. Returns
    /// the per-image logits plus the MAC-equivalent delta updates
    /// performed (changed entries × layer outputs).
    pub fn delta_resume_exact(
        &self,
        anchor: &ReferenceChain,
        ck: &BatchCheckpoint,
    ) -> (Vec<Vec<f32>>, u64) {
        assert_eq!(ck.bsz, anchor.ckpts[0].bsz, "anchor batch mismatch");
        let bsz = ck.bsz;
        let mut delta_macs = 0u64;
        let mut cur: Option<BatchCheckpoint> = None;
        for l in ck.layer..N_LAYERS {
            let acc = {
                let src = cur.as_ref().unwrap_or(ck);
                let layer = self.layer_at(l);
                let (rows_per, k, n) = LAYER_GEMM[l];
                let rows = bsz * rows_per;
                let a0 = &anchor.ckpts[l].a_q;
                debug_assert_eq!(src.a_q.len(), a0.len());
                let mut acc = anchor.accs[l].clone();
                for r in 0..rows {
                    let ar = &src.a_q[r * k..(r + 1) * k];
                    let a0r = &a0[r * k..(r + 1) * k];
                    for e in 0..k {
                        let d = ar[e] as i32 - a0r[e] as i32;
                        if d != 0 {
                            let w_row = &layer.w_q[e * n..(e + 1) * n];
                            let out = &mut acc[r * n..(r + 1) * n];
                            for (o, &w) in out.iter_mut().zip(w_row) {
                                *o += d as i64 * w as i64;
                            }
                            delta_macs += n as u64;
                        }
                    }
                }
                acc
            };
            if l == N_LAYERS - 1 {
                return (self.logits_from_acc(&acc, bsz), delta_macs);
            }
            cur = Some(self.post_ops_checkpoint(l, bsz, &acc));
        }
        unreachable!("loop returns at the last layer")
    }

    /// The batched pipeline for one contiguous image group, expressed as
    /// resumable stages: build the depth-0 checkpoint, then replay every
    /// layer. `gemm_threads` parallelizes inside the GEMMs only (see
    /// [`QuantCnn::forward_batch`] for the group-level split).
    fn forward_batch_core(
        &self,
        luts: &LayerLuts,
        images: &[&[u8]],
        gemm_threads: usize,
    ) -> Vec<Vec<f32>> {
        let ck = self.input_checkpoint(images);
        self.resume_batch_hetero(&ck, luts, gemm_threads)
    }

    /// Forward a batch of images (each a 256-byte 16×16 grayscale) in one
    /// pass: conv layers run as a single blocked GEMM over the stacked
    /// batch-of-N im2col matrix (weight tiles reused across the batch), fc
    /// layers as one GEMM with one row per image.
    ///
    /// With `threads > 1` the batch splits into contiguous image groups,
    /// one per worker, and each group runs the whole pipeline (quantize,
    /// im2col, GEMM, pool) serially — so every stage scales with cores,
    /// not just the GEMM inner loops. A single image with spare threads
    /// instead parallelizes over GEMM row-tiles.
    ///
    /// **Bit-identical** to calling [`QuantCnn::forward`] per image, for
    /// every LUT, batch size, grouping and thread count: each output row's
    /// integer accumulation sums the same products (order-independent),
    /// and every float op (normalize, quantize, bias add, relu, maxpool,
    /// dequantize) is applied per element exactly as in the scalar path.
    /// The equivalence suite (`rust/tests/nn_batch_equivalence.rs`) pins
    /// this down.
    pub fn forward_batch(&self, lut: &[i32], images: &[&[u8]], threads: usize) -> Vec<Vec<f32>> {
        self.forward_batch_hetero(&LayerLuts::uniform(lut), images, threads)
    }

    /// [`QuantCnn::forward_batch`] with a per-layer LUT assignment — the
    /// execution path for compiled heterogeneous plans. Bit-identical to
    /// [`QuantCnn::forward_hetero`] per image for any batch size, grouping
    /// and thread count (same argument as the uniform case: integer
    /// accumulation per output element is order-independent, float ops are
    /// per-element identical), and with [`LayerLuts::uniform`] it *is*
    /// `forward_batch`.
    pub fn forward_batch_hetero(
        &self,
        luts: &LayerLuts,
        images: &[&[u8]],
        threads: usize,
    ) -> Vec<Vec<f32>> {
        let bsz = images.len();
        if bsz == 0 {
            return Vec::new();
        }
        let threads = threads.max(1);
        if threads == 1 || bsz == 1 {
            return self.forward_batch_core(luts, images, threads);
        }
        let groups = threads.min(bsz);
        let base = bsz / groups;
        let rem = bsz % groups;
        let grouped = parallel_map(groups, threads, |g| {
            let start = g * base + g.min(rem);
            let len = base + usize::from(g < rem);
            self.forward_batch_core(luts, &images[start..start + len], 1)
        });
        grouped.into_iter().flatten().collect()
    }

    /// Load from the artifacts directory written by `python/compile/aot.py`
    /// (weights/{name}_q.npy int8-as-i32, weights/{name}_b.npy f32, and
    /// weights/scales.npy = [in1, w1, in2, w2, in3, w3, in4, w4]).
    pub fn load(dir: &Path) -> Result<QuantCnn> {
        let wdir = dir.join("weights");
        let (_, scales) = npy::read_f32(&wdir.join("scales.npy"))
            .context("reading scales.npy — run `make artifacts` first")?;
        if scales.len() != 8 {
            bail!("scales.npy must have 8 entries, got {}", scales.len());
        }
        let load_layer = |name: &str, in_scale: f32, w_scale: f32| -> Result<QuantLayer> {
            let (_, wq) = npy::read_i32(&wdir.join(format!("{name}_q.npy")))?;
            let (_, bias) = npy::read_f32(&wdir.join(format!("{name}_b.npy")))?;
            Ok(QuantLayer {
                w_q: wq.iter().map(|&v| v as i8).collect(),
                w_scale,
                in_scale,
                bias,
            })
        };
        Ok(QuantCnn {
            conv1: load_layer("conv1", scales[0], scales[1])?,
            conv2: load_layer("conv2", scales[2], scales[3])?,
            fc1: load_layer("fc1", scales[4], scales[5])?,
            fc2: load_layer("fc2", scales[6], scales[7])?,
        })
    }

    /// A tiny deterministic random model (for tests without artifacts).
    pub fn random(seed: u64) -> QuantCnn {
        let mut rng = crate::util::rng::Pcg32::new(seed);
        let mut mk = |k: usize, n: usize, in_scale: f32| -> QuantLayer {
            let w_q: Vec<i8> = (0..k * n)
                .map(|_| (rng.below(255) as i64 - 127) as i8)
                .collect();
            QuantLayer {
                w_q,
                w_scale: 0.02,
                in_scale,
                bias: (0..n).map(|_| (rng.next_f64() as f32 - 0.5) * 0.1).collect(),
            }
        };
        QuantCnn {
            conv1: mk(9, C1_OUT, 1.0 / 127.0),
            conv2: mk(72, C2_OUT, 0.05),
            fc1: mk(64, FC1_OUT, 0.05),
            fc2: mk(FC1_OUT, CLASSES, 0.05),
        }
    }
}

/// `n` deterministic pseudo-random 16×16 grayscale images (flattened to
/// `n * 256` bytes) — the artifact-free workload for benches, the serving
/// soak test, and `--backend native` demos without a dataset on disk.
pub fn synthetic_images(n: usize, seed: u64) -> Vec<u8> {
    let mut rng = crate::util::rng::Pcg32::new(seed);
    (0..n * IMG * IMG).map(|_| rng.below(256) as u8).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::MultFamily;
    use crate::mult::behavioral::int8_lut;

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn forward_shapes_and_determinism() {
        let cnn = QuantCnn::random(7);
        let lut = int8_lut(&MultFamily::Exact);
        let img: Vec<u8> = (0..256).map(|i| (i * 7 % 256) as u8).collect();
        let a = cnn.forward(&lut, &img);
        let b = cnn.forward(&lut, &img);
        assert_eq!(a.len(), 10);
        assert_eq!(a, b);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn different_luts_give_close_but_different_logits() {
        let cnn = QuantCnn::random(3);
        let exact = int8_lut(&MultFamily::Exact);
        let logour = int8_lut(&MultFamily::LogOur);
        let img: Vec<u8> = (0..256).map(|i| ((i * 13) % 256) as u8).collect();
        let le = cnn.forward(&exact, &img);
        let ll = cnn.forward(&logour, &img);
        assert_ne!(le, ll);
        let scale: f32 = le.iter().map(|x| x.abs()).sum::<f32>() / 10.0;
        let dev: f32 = le
            .iter()
            .zip(&ll)
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / 10.0;
        assert!(dev < 0.5 * scale, "dev {dev} vs scale {scale}");
    }

    #[test]
    fn forward_batch_matches_forward_small() {
        // Debug-friendly bit-exactness smoke (the full family × batch-size
        // matrix lives in rust/tests/nn_batch_equivalence.rs).
        let cnn = QuantCnn::random(7);
        let mut lut = vec![0i32; 65536];
        for a in -128i32..=127 {
            for b in -128i32..=127 {
                lut[(((a as u8) as usize) << 8) | ((b as u8) as usize)] = a * b;
            }
        }
        let images = synthetic_images(2, 3);
        let views: Vec<&[u8]> = images.chunks(IMG * IMG).collect();
        let batched = cnn.forward_batch(&lut, &views, 2);
        assert_eq!(batched.len(), 2);
        for (i, v) in views.iter().enumerate() {
            assert_eq!(batched[i], cnn.forward(&lut, v), "image {i}");
        }
    }

    #[test]
    fn layer_macs_match_architecture() {
        // conv1: 14·14 patches × 9 taps × 8 out; conv2: 5·5 × 72 × 16;
        // fc1: 64×32; fc2: 32×10.
        assert_eq!(layer_macs_per_image(), [14112, 28800, 2048, 320]);
    }

    #[test]
    fn hetero_uniform_is_bit_identical_to_uniform() {
        let cnn = QuantCnn::random(11);
        let mut lut = vec![0i32; 65536];
        for a in -128i32..=127 {
            for b in -128i32..=127 {
                lut[(((a as u8) as usize) << 8) | ((b as u8) as usize)] = a * b;
            }
        }
        let images = synthetic_images(3, 9);
        let views: Vec<&[u8]> = images.chunks(IMG * IMG).collect();
        let uniform = cnn.forward_batch(&lut, &views, 2);
        let hetero = cnn.forward_batch_hetero(&LayerLuts::uniform(&lut), &views, 2);
        assert_eq!(uniform, hetero);
        assert_eq!(
            cnn.forward(&lut, views[0]),
            cnn.forward_hetero(&LayerLuts::uniform(&lut), views[0])
        );
    }

    #[test]
    fn hetero_layer_swap_changes_only_that_layer_path() {
        // Swapping fc2's LUT to all-zeros must leave conv/fc1 outputs
        // intact: logits collapse to exactly the fc2 biases.
        let cnn = QuantCnn::random(4);
        let mut exact = vec![0i32; 65536];
        for a in -128i32..=127 {
            for b in -128i32..=127 {
                exact[(((a as u8) as usize) << 8) | ((b as u8) as usize)] = a * b;
            }
        }
        let zero = vec![0i32; 65536];
        let images = synthetic_images(2, 21);
        let views: Vec<&[u8]> = images.chunks(IMG * IMG).collect();
        let luts = LayerLuts {
            conv1: &exact,
            conv2: &exact,
            fc1: &exact,
            fc2: &zero,
        };
        for row in cnn.forward_batch_hetero(&luts, &views, 1) {
            for (j, v) in row.iter().enumerate() {
                assert_eq!(*v, cnn.fc2.bias[j]);
            }
        }
    }

    fn exact_lut() -> Vec<i32> {
        int8_lut(&MultFamily::Exact)
    }

    /// A deliberately perturbed (non-linear) LUT whose zero row stays
    /// zero: `a*b` with the low bit of odd·odd products cleared.
    fn perturbed_lut() -> Vec<i32> {
        let mut lut = exact_lut();
        for a in -128i32..=127 {
            for b in -128i32..=127 {
                if a % 2 != 0 && b % 2 != 0 {
                    let idx = (((a as u8) as usize) << 8) | ((b as u8) as usize);
                    lut[idx] &= !1;
                }
            }
        }
        lut
    }

    #[test]
    fn layer_gemm_geometry_matches_macs() {
        for (l, &(rows, k, n)) in LAYER_GEMM.iter().enumerate() {
            assert_eq!((rows * k * n) as u64, layer_macs_per_image()[l], "layer {l}");
        }
    }

    #[test]
    fn checkpoint_replay_from_every_depth_matches_forward() {
        let cnn = QuantCnn::random(13);
        let exact = exact_lut();
        let pert = perturbed_lut();
        let luts = LayerLuts {
            conv1: &pert,
            conv2: &exact,
            fc1: &pert,
            fc2: &exact,
        };
        let images = synthetic_images(3, 31);
        let views: Vec<&[u8]> = images.chunks(IMG * IMG).collect();
        let full = cnn.forward_batch_hetero(&luts, &views, 2);
        let mut ck = cnn.input_checkpoint(&views);
        for depth in 0..N_LAYERS {
            let replay = cnn.resume_batch_hetero(&ck, &luts, 1);
            assert_eq!(replay, full, "replay from depth {depth}");
            if depth < N_LAYERS - 1 {
                ck = cnn.advance_checkpoint(&ck, luts.get(depth), 1);
                assert_eq!(ck.layer(), depth + 1);
                assert_eq!(ck.batch(), 3);
            }
        }
    }

    #[test]
    fn delta_resume_matches_full_replay() {
        // Swap one layer to the perturbed LUT, keep everything downstream
        // exact: the sparse delta replay must reproduce the full forward
        // bit-for-bit from the anchor's accumulators.
        let cnn = QuantCnn::random(23);
        let exact = exact_lut();
        let pert = perturbed_lut();
        let images = synthetic_images(4, 77);
        let views: Vec<&[u8]> = images.chunks(IMG * IMG).collect();
        let anchor = cnn.reference_chain(&LayerLuts::uniform(&exact), &views, 1);
        // The anchor's own logits equal the plain exact forward.
        assert_eq!(
            anchor.logits().to_vec(),
            cnn.forward_batch(&exact, &views, 1)
        );
        for swapped in 0..N_LAYERS - 1 {
            let mut luts = LayerLuts::uniform(&exact);
            match swapped {
                0 => luts.conv1 = &pert,
                1 => luts.conv2 = &pert,
                _ => luts.fc1 = &pert,
            }
            let full = cnn.forward_batch_hetero(&luts, &views, 1);
            let next = cnn.advance_checkpoint(anchor.checkpoint(swapped), &pert, 1);
            let (logits, dmacs) = cnn.delta_resume_exact(&anchor, &next);
            assert_eq!(logits, full, "swapped layer {swapped}");
            // The delta replay must touch strictly fewer MAC-equivalents
            // than the full suffix it replaces.
            let full_suffix: u64 = layer_macs_per_image()[swapped + 1..]
                .iter()
                .sum::<u64>()
                * views.len() as u64;
            assert!(dmacs < full_suffix, "layer {swapped}: {dmacs} vs {full_suffix}");
        }
    }

    #[test]
    fn im2col_batch_stacks_per_image_blocks() {
        let x: Vec<i8> = (1..=18).collect(); // two 3x3 images
        let (cols, m, k) = super::im2col_batch_i8(&x, 2, 3, 3, 1, 2);
        assert_eq!((m, k), (4, 4));
        assert_eq!(cols.len(), 2 * 4 * 4);
        let (one, _, _) = super::im2col_gen(&x[0..9], 3, 3, 1, 2, 0i8);
        let (two, _, _) = super::im2col_gen(&x[9..18], 3, 3, 1, 2, 0i8);
        assert_eq!(&cols[0..16], &one[..]);
        assert_eq!(&cols[16..32], &two[..]);
    }

    #[test]
    fn im2col_reference() {
        // 3x3 single-channel input, k=2 → 4 rows of 4 values.
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let (cols, m, k) = super::im2col(&x, 3, 3, 1, 2);
        assert_eq!((m, k), (4, 4));
        assert_eq!(&cols[0..4], &[1.0, 2.0, 4.0, 5.0]);
        assert_eq!(&cols[12..16], &[5.0, 6.0, 8.0, 9.0]);
    }

    #[test]
    fn maxpool_reference() {
        let x = vec![1.0, 2.0, 3.0, 4.0]; // 2x2x1
        let (p, h, w) = super::maxpool2(&x, 2, 2, 1);
        assert_eq!((h, w), (1, 1));
        assert_eq!(p, vec![4.0]);
    }
}
