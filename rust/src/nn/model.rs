//! Rust-native quantized CNN forward — the mirror of
//! `python/compile/model.py` (same architecture, same static quantization,
//! same LUT-routed multiplies). Used to cross-check the AOT JAX graph and
//! as a fallback evaluator when PJRT artifacts are absent.
//!
//! Architecture (16×16×1 input, 10 classes):
//!   conv3x3(1→8) + relu + maxpool2 → conv3x3(8→16) + relu + maxpool2
//!   → flatten(2·2·16=64)… wait: 16→14→7→5→2 — flatten 2×2×16 = 64
//!   → fc(64→32) + relu → fc(32→10).

use anyhow::{bail, Context, Result};
use std::path::Path;

use super::quant::{lut_matmul, quantize_all};
use crate::util::npy;

/// One quantized layer: int8 weights + scales.
#[derive(Clone, Debug)]
pub struct QuantLayer {
    /// Quantized weights, layout documented per use.
    pub w_q: Vec<i8>,
    pub w_scale: f32,
    /// Input activation scale (calibrated).
    pub in_scale: f32,
    /// float bias.
    pub bias: Vec<f32>,
}

/// The full quantized CNN.
#[derive(Clone, Debug)]
pub struct QuantCnn {
    /// conv1: [out=8, in=1, 3, 3] flattened as (9) × 8 matrix after im2col.
    pub conv1: QuantLayer,
    /// conv2: [out=16, in=8, 3, 3] → (72) × 16.
    pub conv2: QuantLayer,
    /// fc1: 64 × 32.
    pub fc1: QuantLayer,
    /// fc2: 32 × 10.
    pub fc2: QuantLayer,
}

pub const IMG: usize = 16;
pub const C1_OUT: usize = 8;
pub const C2_OUT: usize = 16;
pub const FC1_OUT: usize = 32;
pub const CLASSES: usize = 10;

fn im2col(
    input: &[f32],
    h: usize,
    w: usize,
    c: usize,
    k: usize,
) -> (Vec<f32>, usize, usize) {
    // input layout HWC; output rows = (h-k+1)*(w-k+1), cols = k*k*c
    let oh = h - k + 1;
    let ow = w - k + 1;
    let cols = k * k * c;
    let mut out = vec![0f32; oh * ow * cols];
    for oy in 0..oh {
        for ox in 0..ow {
            let row = oy * ow + ox;
            let mut idx = 0;
            for ky in 0..k {
                for kx in 0..k {
                    for ch in 0..c {
                        out[row * cols + idx] = input[((oy + ky) * w + (ox + kx)) * c + ch];
                        idx += 1;
                    }
                }
            }
        }
    }
    (out, oh * ow, cols)
}

fn relu(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        if *x < 0.0 {
            *x = 0.0;
        }
    }
}

fn maxpool2(input: &[f32], h: usize, w: usize, c: usize) -> (Vec<f32>, usize, usize) {
    let oh = h / 2;
    let ow = w / 2;
    let mut out = vec![f32::MIN; oh * ow * c];
    for y in 0..oh {
        for x in 0..ow {
            for ch in 0..c {
                let mut m = f32::MIN;
                for dy in 0..2 {
                    for dx in 0..2 {
                        m = m.max(input[((2 * y + dy) * w + (2 * x + dx)) * c + ch]);
                    }
                }
                out[(y * ow + x) * c + ch] = m;
            }
        }
    }
    (out, oh, ow)
}

impl QuantCnn {
    /// Quantized conv/fc as im2col + LUT matmul + bias.
    fn layer_forward(
        &self,
        lut: &[i32],
        layer: &QuantLayer,
        input: &[f32],
        m: usize,
        k: usize,
        n: usize,
    ) -> Vec<f32> {
        let a_q = quantize_all(input, layer.in_scale);
        let mut out = lut_matmul(lut, &a_q, &layer.w_q, m, k, n, layer.in_scale, layer.w_scale);
        for row in 0..m {
            for j in 0..n {
                out[row * n + j] += layer.bias[j];
            }
        }
        out
    }

    /// Forward one image (u8 16×16 grayscale) → 10 logits.
    pub fn forward(&self, lut: &[i32], image: &[u8]) -> Vec<f32> {
        assert_eq!(image.len(), IMG * IMG);
        // Normalize to [0,1].
        let x: Vec<f32> = image.iter().map(|&p| p as f32 / 255.0).collect();
        // conv1
        let (cols, m, k) = im2col(&x, IMG, IMG, 1, 3);
        let mut h1 = self.layer_forward(lut, &self.conv1, &cols, m, k, C1_OUT);
        relu(&mut h1);
        let (p1, h1h, h1w) = maxpool2(&h1, IMG - 2, IMG - 2, C1_OUT);
        // conv2
        let (cols2, m2, k2) = im2col(&p1, h1h, h1w, C1_OUT, 3);
        let mut h2 = self.layer_forward(lut, &self.conv2, &cols2, m2, k2, C2_OUT);
        relu(&mut h2);
        let (p2, p2h, p2w) = maxpool2(&h2, h1h - 2, h1w - 2, C2_OUT);
        // flatten → fc1 → fc2
        let flat_len = p2h * p2w * C2_OUT;
        let mut h3 = self.layer_forward(lut, &self.fc1, &p2, 1, flat_len, FC1_OUT);
        relu(&mut h3);
        self.layer_forward(lut, &self.fc2, &h3, 1, FC1_OUT, CLASSES)
    }

    /// Load from the artifacts directory written by `python/compile/aot.py`
    /// (weights/{name}_q.npy int8-as-i32, weights/{name}_b.npy f32, and
    /// weights/scales.npy = [in1, w1, in2, w2, in3, w3, in4, w4]).
    pub fn load(dir: &Path) -> Result<QuantCnn> {
        let wdir = dir.join("weights");
        let (_, scales) = npy::read_f32(&wdir.join("scales.npy"))
            .context("reading scales.npy — run `make artifacts` first")?;
        if scales.len() != 8 {
            bail!("scales.npy must have 8 entries, got {}", scales.len());
        }
        let load_layer = |name: &str, in_scale: f32, w_scale: f32| -> Result<QuantLayer> {
            let (_, wq) = npy::read_i32(&wdir.join(format!("{name}_q.npy")))?;
            let (_, bias) = npy::read_f32(&wdir.join(format!("{name}_b.npy")))?;
            Ok(QuantLayer {
                w_q: wq.iter().map(|&v| v as i8).collect(),
                w_scale,
                in_scale,
                bias,
            })
        };
        Ok(QuantCnn {
            conv1: load_layer("conv1", scales[0], scales[1])?,
            conv2: load_layer("conv2", scales[2], scales[3])?,
            fc1: load_layer("fc1", scales[4], scales[5])?,
            fc2: load_layer("fc2", scales[6], scales[7])?,
        })
    }

    /// A tiny deterministic random model (for tests without artifacts).
    pub fn random(seed: u64) -> QuantCnn {
        let mut rng = crate::util::rng::Pcg32::new(seed);
        let mut mk = |k: usize, n: usize, in_scale: f32| -> QuantLayer {
            let w_q: Vec<i8> = (0..k * n)
                .map(|_| (rng.below(255) as i64 - 127) as i8)
                .collect();
            QuantLayer {
                w_q,
                w_scale: 0.02,
                in_scale,
                bias: (0..n).map(|_| (rng.next_f64() as f32 - 0.5) * 0.1).collect(),
            }
        };
        QuantCnn {
            conv1: mk(9, C1_OUT, 1.0 / 127.0),
            conv2: mk(72, C2_OUT, 0.05),
            fc1: mk(64, FC1_OUT, 0.05),
            fc2: mk(FC1_OUT, CLASSES, 0.05),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::MultFamily;
    use crate::mult::behavioral::int8_lut;

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn forward_shapes_and_determinism() {
        let cnn = QuantCnn::random(7);
        let lut = int8_lut(&MultFamily::Exact);
        let img: Vec<u8> = (0..256).map(|i| (i * 7 % 256) as u8).collect();
        let a = cnn.forward(&lut, &img);
        let b = cnn.forward(&lut, &img);
        assert_eq!(a.len(), 10);
        assert_eq!(a, b);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn different_luts_give_close_but_different_logits() {
        let cnn = QuantCnn::random(3);
        let exact = int8_lut(&MultFamily::Exact);
        let logour = int8_lut(&MultFamily::LogOur);
        let img: Vec<u8> = (0..256).map(|i| ((i * 13) % 256) as u8).collect();
        let le = cnn.forward(&exact, &img);
        let ll = cnn.forward(&logour, &img);
        assert_ne!(le, ll);
        let scale: f32 = le.iter().map(|x| x.abs()).sum::<f32>() / 10.0;
        let dev: f32 = le
            .iter()
            .zip(&ll)
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / 10.0;
        assert!(dev < 0.5 * scale, "dev {dev} vs scale {scale}");
    }

    #[test]
    fn im2col_reference() {
        // 3x3 single-channel input, k=2 → 4 rows of 4 values.
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let (cols, m, k) = super::im2col(&x, 3, 3, 1, 2);
        assert_eq!((m, k), (4, 4));
        assert_eq!(&cols[0..4], &[1.0, 2.0, 4.0, 5.0]);
        assert_eq!(&cols[12..16], &[5.0, 6.0, 8.0, 9.0]);
    }

    #[test]
    fn maxpool_reference() {
        let x = vec![1.0, 2.0, 3.0, 4.0]; // 2x2x1
        let (p, h, w) = super::maxpool2(&x, 2, 2, 1);
        assert_eq!((h, w), (1, 1));
        assert_eq!(p, vec![4.0]);
    }
}
