//! Neural-network evaluation under approximate multipliers (paper §V-B,
//! Table IV).
//!
//! The paper runs a pre-trained ResNet-18 on ILSVRC2012 with every multiply
//! replaced by an approximate multiplier; our substitution (DESIGN.md §3)
//! is a small CNN trained (build-time, in JAX) on a deterministic synthetic
//! 10-class dataset, with the identical multiplier-substitution protocol:
//! int8 sign-magnitude quantization, every conv/fc product routed through
//! the 8-bit multiplier LUT.
//!
//! * [`quant`] — the static symmetric quantization scheme (mirrors
//!   `python/compile/mults.py` / `model.py` exactly) plus the two LUT-GEMM
//!   kernels: the naive reference ([`quant::lut_matmul`]) and the
//!   tile-blocked, threadpool-parallel batched kernel
//!   ([`quant::lut_matmul_batched`]), proven bit-identical;
//! * [`model`] — the Rust-native quantized CNN: scalar
//!   [`QuantCnn::forward`] (the oracle) and batched
//!   [`QuantCnn::forward_batch`] (the serving path behind
//!   [`crate::runtime::NativeBackend`]). Both dispatch through
//!   [`model::LayerLuts`] — one LUT per layer — so heterogeneous
//!   per-layer multiplier assignments (the [`crate::compile`] pass's
//!   output) execute on the same code path as the uniform configuration
//!   ([`QuantCnn::forward_hetero`] / [`QuantCnn::forward_batch_hetero`]).
//!   The batched pipeline is split into resumable per-layer stages
//!   ([`model::BatchCheckpoint`], [`model::ReferenceChain`]) so the
//!   compile search replays only the suffix a candidate assignment
//!   actually changes;
//! * [`eval`] — Top-1/Top-5 scoring (NaN-safe total ordering);
//! * [`cli`] — `openacm nn`: Table IV (accuracy + NMED/MRED).

pub mod quant;
pub mod model;
pub mod eval;
pub mod cli;

pub use eval::{argmax, topk_accuracy, EvalResult};
pub use model::{synthetic_images, BatchCheckpoint, LayerLuts, QuantCnn, ReferenceChain};
