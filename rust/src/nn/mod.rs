//! Neural-network evaluation under approximate multipliers (paper §V-B,
//! Table IV).
//!
//! The paper runs a pre-trained ResNet-18 on ILSVRC2012 with every multiply
//! replaced by an approximate multiplier; our substitution (DESIGN.md §3)
//! is a small CNN trained (build-time, in JAX) on a deterministic synthetic
//! 10-class dataset, with the identical multiplier-substitution protocol:
//! int8 sign-magnitude quantization, every conv/fc product routed through
//! the 8-bit multiplier LUT.
//!
//! * [`quant`] — the static symmetric quantization scheme (mirrors
//!   `python/compile/mults.py` / `model.py` exactly);
//! * [`model`] — the Rust-native quantized CNN forward (LUT matmul), used
//!   to cross-check the AOT JAX graph and as a no-artifacts fallback;
//! * [`eval`] — Top-1/Top-5 scoring;
//! * [`cli`] — `openacm nn`: Table IV (accuracy + NMED/MRED).

pub mod quant;
pub mod model;
pub mod eval;
pub mod cli;

pub use eval::{topk_accuracy, EvalResult};
pub use model::QuantCnn;
