//! Content-addressed, sharded, persistent design-point store.
//!
//! Every OpenACM design point — a `(netlist structure, characterization
//! parameters)` pair — is fully deterministic, yet the DSE sweep, the PPA
//! engine and the functional-yield MC historically recomputed everything
//! from scratch on every invocation. This subsystem turns repeated sweeps,
//! Pareto refinements and coordinator warm-starts from *O(full recompute)*
//! into *O(disk read)*:
//!
//! * [`key`] — canonical structural hashing of [`crate::gates::Netlist`]
//!   plus characterization parameters into a stable 128-bit [`Key128`]
//!   (MurmurHash3 x64-128 over a tagged canonical byte encoding);
//! * [`record`] — versioned binary [`DesignPointRecord`]s (error metrics,
//!   per-net activity, PPA summary, functional-yield stats) with a checksum
//!   footer, written via temp-file + atomic rename so torn writes are
//!   detected and recomputed, never trusted;
//! * [`DesignPointStore`] — a sharded in-memory index (one `RwLock` shard
//!   per hash-prefix bucket) over an on-disk two-level directory layout,
//!   with hit/miss/write/evict/corrupt counters, integrity [`verify`] and a
//!   size-bounded, oldest-first [`gc`].
//!
//! On-disk layout: `<root>/<hh>/<32-hex-key>.dpr` where `hh` is the key's
//! top byte — 256-way fan-out keeps directories small at millions of
//! records. Writers serialize to `<root>/<hh>/.tmp-*` and `rename(2)` into
//! place, so concurrent writers of the same key race benignly (last full
//! record wins; readers only ever observe complete files).
//!
//! [`verify`]: DesignPointStore::verify
//! [`gc`]: DesignPointStore::gc

pub mod cli;
pub mod key;
pub mod record;
pub(crate) mod wire;

pub use key::{Key128, KeyBuilder};
pub use record::{
    AccuracyStats, ActivityStats, DesignPointRecord, ErrorStats, PpaSummary, YieldStats,
    FORMAT_VERSION,
};

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// Number of index shards (keyed by the top bits of the hash). Lock
/// contention is per-shard, so concurrent sweep workers rarely collide.
const SHARDS: usize = 16;

/// Record file extension.
const EXT: &str = "dpr";

#[derive(Clone, Copy, Debug)]
struct IndexEntry {
    bytes: u64,
    /// Modification time as nanos since epoch (eviction order).
    mtime_ns: u64,
}

/// Aggregate counters, readable at any time (e.g. printed by
/// `examples/dse_pareto.rs` and asserted by the warm-sweep integration
/// test).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub hits: u64,
    pub misses: u64,
    pub writes: u64,
    pub evictions: u64,
    /// Records rejected by validation (bad magic/version/checksum) — each
    /// one became a miss + recompute instead of garbage data.
    pub corrupt: u64,
    /// Records currently indexed.
    pub records: u64,
    /// Total indexed bytes on disk.
    pub bytes: u64,
}

impl StoreStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }

    /// One-line human summary shared by the CLI/example reporters.
    pub fn summary(&self) -> String {
        format!(
            "{} hits / {} misses ({:.0}% hit rate), {} records / {:.2} MB on disk",
            self.hits,
            self.misses,
            self.hit_rate() * 100.0,
            self.records,
            self.bytes as f64 / 1e6
        )
    }

    /// Counter deltas since an earlier snapshot (per-phase accounting).
    pub fn since(&self, earlier: &StoreStats) -> StoreStats {
        StoreStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            writes: self.writes - earlier.writes,
            evictions: self.evictions - earlier.evictions,
            corrupt: self.corrupt - earlier.corrupt,
            records: self.records,
            bytes: self.bytes,
        }
    }
}

/// Result of a full-store integrity scan.
#[derive(Clone, Debug, Default)]
pub struct VerifyReport {
    pub checked: u64,
    pub ok: u64,
    pub corrupt: Vec<PathBuf>,
}

/// The persistent characterization store. All methods take `&self` and are
/// safe to call from many threads (sweep workers cache-fill concurrently).
pub struct DesignPointStore {
    root: PathBuf,
    shards: Vec<RwLock<HashMap<u128, IndexEntry>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    writes: AtomicU64,
    evictions: AtomicU64,
    corrupt: AtomicU64,
    tmp_counter: AtomicU64,
    /// Process-wide registry mirrors of the counters above (`store.*`).
    /// The per-store atomics stay authoritative for [`StoreStats`] (a
    /// process can hold several stores); these feed `openacm obs`.
    obs: ObsCounters,
}

/// Registry handles mirrored by every store op (see `obs::registry`).
#[derive(Debug)]
struct ObsCounters {
    hits: crate::obs::Counter,
    misses: crate::obs::Counter,
    writes: crate::obs::Counter,
    evictions: crate::obs::Counter,
    corrupt: crate::obs::Counter,
}

impl ObsCounters {
    fn new() -> ObsCounters {
        ObsCounters {
            hits: crate::obs::counter("store.hits"),
            misses: crate::obs::counter("store.misses"),
            writes: crate::obs::counter("store.writes"),
            evictions: crate::obs::counter("store.evictions"),
            corrupt: crate::obs::counter("store.corrupt"),
        }
    }
}

impl DesignPointStore {
    /// Default store root: `$OPENACM_STORE` or `.openacm_store` in the
    /// working directory.
    pub fn default_dir() -> PathBuf {
        std::env::var("OPENACM_STORE")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from(".openacm_store"))
    }

    /// Open (creating if needed) a store rooted at `root` and index every
    /// record already on disk.
    pub fn open(root: &Path) -> Result<DesignPointStore> {
        fs::create_dir_all(root)
            .with_context(|| format!("creating store root {}", root.display()))?;
        let store = DesignPointStore {
            root: root.to_path_buf(),
            shards: (0..SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            writes: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            corrupt: AtomicU64::new(0),
            tmp_counter: AtomicU64::new(0),
            obs: ObsCounters::new(),
        };
        store.rescan()?;
        // Boot-time footprint gauges: serving warm-start only *scans* the
        // store, so these are what make a read-only open visible in
        // `openacm obs snapshot`.
        let s = store.stats();
        crate::obs::gauge("store.records").set(s.records as i64);
        crate::obs::gauge("store.bytes").set(s.bytes as i64);
        crate::obs::counter("store.opens").inc();
        Ok(store)
    }

    /// Rebuild the in-memory index from the on-disk layout.
    pub fn rescan(&self) -> Result<()> {
        for shard in &self.shards {
            shard.write().unwrap().clear();
        }
        let Ok(top) = fs::read_dir(&self.root) else {
            return Ok(());
        };
        for dir in top.flatten() {
            if !dir.file_type().map(|t| t.is_dir()).unwrap_or(false) {
                continue;
            }
            let Ok(files) = fs::read_dir(dir.path()) else {
                continue;
            };
            for f in files.flatten() {
                let path = f.path();
                if path.extension().and_then(|e| e.to_str()) != Some(EXT) {
                    // Reclaim temp files orphaned by crashed writers. Only
                    // stale ones: a live writer in another process may be
                    // about to rename a fresh `.tmp-*` into place.
                    let stale_ns = 3_600_000_000_000u64; // 1 hour
                    let is_tmp = path
                        .file_name()
                        .and_then(|n| n.to_str())
                        .is_some_and(|n| n.starts_with(".tmp-"));
                    if is_tmp {
                        if let Ok(meta) = f.metadata() {
                            if now_ns().saturating_sub(mtime_ns(&meta)) > stale_ns {
                                let _ = fs::remove_file(&path);
                            }
                        }
                    }
                    continue;
                }
                let Some(key) = path
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .and_then(Key128::from_hex)
                else {
                    continue;
                };
                if let Ok(meta) = f.metadata() {
                    self.shard(key)
                        .write()
                        .unwrap()
                        .insert(key.0, IndexEntry { bytes: meta.len(), mtime_ns: mtime_ns(&meta) });
                }
            }
        }
        Ok(())
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// On-disk path of one key (`<root>/<hh>/<32-hex>.dpr`).
    pub fn path_for(&self, key: Key128) -> PathBuf {
        self.root
            .join(format!("{:02x}", key.shard_byte()))
            .join(format!("{}.{EXT}", key.hex()))
    }

    fn shard(&self, key: Key128) -> &RwLock<HashMap<u128, IndexEntry>> {
        &self.shards[(key.shard_byte() as usize) % SHARDS]
    }

    /// Look up one record. Reads and fully validates the on-disk bytes; a
    /// missing file is a miss, and a record that fails validation (torn
    /// write, bit rot, format-version skew) is dropped, counted under
    /// `corrupt`, and reported as a miss so the caller recomputes.
    pub fn get(&self, key: Key128) -> Option<DesignPointRecord> {
        let path = self.path_for(key);
        let bytes = match fs::read(&path) {
            Ok(b) => b,
            Err(_) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                self.obs.misses.inc();
                return None;
            }
        };
        match DesignPointRecord::decode(&bytes, Some(key)) {
            Ok((_, rec)) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                self.obs.hits.inc();
                Some(rec)
            }
            Err(_) => {
                self.corrupt.fetch_add(1, Ordering::Relaxed);
                self.misses.fetch_add(1, Ordering::Relaxed);
                self.obs.corrupt.inc();
                self.obs.misses.inc();
                let _ = fs::remove_file(&path);
                self.shard(key).write().unwrap().remove(&key.0);
                None
            }
        }
    }

    /// Persist one record: serialize with checksum footer, write to a
    /// shard-local temp file, then atomically rename into place.
    pub fn put(&self, key: Key128, record: &DesignPointRecord) -> Result<()> {
        let path = self.path_for(key);
        let dir = path.parent().expect("record path has a shard dir");
        fs::create_dir_all(dir).with_context(|| format!("creating shard dir {}", dir.display()))?;
        let bytes = record.encode(key);
        let tmp = dir.join(format!(
            ".tmp-{}-{}-{}",
            key.hex(),
            std::process::id(),
            self.tmp_counter.fetch_add(1, Ordering::Relaxed)
        ));
        {
            let mut f = fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(&bytes)?;
            f.sync_all().ok();
        }
        if let Err(e) = fs::rename(&tmp, &path) {
            let _ = fs::remove_file(&tmp);
            return Err(e).with_context(|| format!("renaming into {}", path.display()));
        }
        self.writes.fetch_add(1, Ordering::Relaxed);
        self.obs.writes.inc();
        self.shard(key).write().unwrap().insert(
            key.0,
            IndexEntry { bytes: bytes.len() as u64, mtime_ns: now_ns() },
        );
        Ok(())
    }

    /// Cache-through convenience: return the stored record for `key`, or
    /// compute + persist it. The `bool` is `true` on a hit. A failed write
    /// degrades to cache-off behavior (the computed record is still
    /// returned).
    pub fn get_or_put_with<F: FnOnce() -> DesignPointRecord>(
        &self,
        key: Key128,
        compute: F,
    ) -> (DesignPointRecord, bool) {
        if let Some(rec) = self.get(key) {
            return (rec, true);
        }
        let rec = compute();
        let _ = self.put(key, &rec);
        (rec, false)
    }

    /// Counter + size snapshot.
    pub fn stats(&self) -> StoreStats {
        let mut records = 0u64;
        let mut bytes = 0u64;
        for shard in &self.shards {
            let s = shard.read().unwrap();
            records += s.len() as u64;
            bytes += s.values().map(|e| e.bytes).sum::<u64>();
        }
        StoreStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            writes: self.writes.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            corrupt: self.corrupt.load(Ordering::Relaxed),
            records,
            bytes,
        }
    }

    /// Visit every currently-indexed record that still validates. This is
    /// a *read-only* scan: corrupt records are skipped without touching
    /// the hit/miss/corrupt counters and without deleting anything (that
    /// is `verify --repair`'s opt-in job, or a real lookup's). Used by the
    /// coordinator warm-start and `store stats`.
    pub fn for_each_record<F: FnMut(Key128, &DesignPointRecord)>(&self, mut f: F) {
        for key in self.indexed_keys() {
            if let Some(rec) = self.read_quiet(key) {
                f(key, &rec);
            }
        }
    }

    /// Read + validate one record with no side effects (no counters, no
    /// corrupt-file deletion) — the primitive behind read-only scans.
    fn read_quiet(&self, key: Key128) -> Option<DesignPointRecord> {
        let bytes = fs::read(self.path_for(key)).ok()?;
        DesignPointRecord::decode(&bytes, Some(key))
            .ok()
            .map(|(_, rec)| rec)
    }

    /// Full integrity scan (`openacm store verify`). With `repair`, corrupt
    /// files are deleted so the next access recomputes them.
    pub fn verify(&self, repair: bool) -> VerifyReport {
        let mut report = VerifyReport::default();
        for key in self.indexed_keys() {
            let path = self.path_for(key);
            report.checked += 1;
            let ok = fs::read(&path)
                .ok()
                .and_then(|b| DesignPointRecord::decode(&b, Some(key)).ok())
                .is_some();
            if ok {
                report.ok += 1;
            } else {
                // Reported on the VerifyReport only — the persistent
                // `corrupt` counter tracks lookups that fell back to
                // recompute, and a scan is not a lookup (re-running verify
                // must not inflate it).
                report.corrupt.push(path.clone());
                if repair {
                    let _ = fs::remove_file(&path);
                    self.shard(key).write().unwrap().remove(&key.0);
                }
            }
        }
        report
    }

    /// Size-bounded GC: evict oldest-first until the indexed footprint is
    /// within `max_bytes`. Returns the number of evicted records.
    pub fn gc(&self, max_bytes: u64) -> u64 {
        let mut entries: Vec<(Key128, IndexEntry)> = Vec::new();
        for shard in &self.shards {
            let s = shard.read().unwrap();
            entries.extend(s.iter().map(|(&k, &e)| (Key128(k), e)));
        }
        let mut total: u64 = entries.iter().map(|(_, e)| e.bytes).sum();
        if total <= max_bytes {
            return 0;
        }
        // Oldest first; key breaks mtime ties deterministically.
        entries.sort_by_key(|(k, e)| (e.mtime_ns, k.0));
        let mut evicted = 0u64;
        for (key, entry) in entries {
            if total <= max_bytes {
                break;
            }
            let _ = fs::remove_file(self.path_for(key));
            self.shard(key).write().unwrap().remove(&key.0);
            total -= entry.bytes;
            evicted += 1;
        }
        self.evictions.fetch_add(evicted, Ordering::Relaxed);
        self.obs.evictions.add(evicted);
        evicted
    }

    fn indexed_keys(&self) -> Vec<Key128> {
        let mut keys: Vec<Key128> = self
            .shards
            .iter()
            .flat_map(|s| s.read().unwrap().keys().map(|&k| Key128(k)).collect::<Vec<_>>())
            .collect();
        keys.sort();
        keys
    }
}

fn mtime_ns(meta: &fs::Metadata) -> u64 {
    meta.modified()
        .ok()
        .and_then(|t| t.duration_since(std::time::UNIX_EPOCH).ok())
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0)
}

fn now_ns() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "openacm_store_unit_{tag}_{}_{}",
            std::process::id(),
            now_ns()
        ))
    }

    fn rec(i: u64) -> DesignPointRecord {
        DesignPointRecord {
            family: format!("fam{i}"),
            bits: 8,
            rows: 16,
            n_ops: i,
            seed: i * 3,
            error: Some(ErrorStats {
                nmed: i as f64 * 1e-4,
                mred: 0.0,
                error_rate: 0.5,
                wce: i,
                normalized_bias: 0.0,
                samples: 100,
            }),
            ..Default::default()
        }
    }

    #[test]
    fn put_get_persists_across_reopen() {
        let dir = scratch("reopen");
        let key = KeyBuilder::new("unit/1").u64(42).finish();
        {
            let store = DesignPointStore::open(&dir).unwrap();
            assert!(store.get(key).is_none());
            store.put(key, &rec(42)).unwrap();
            assert_eq!(store.get(key).unwrap(), rec(42));
        }
        let store = DesignPointStore::open(&dir).unwrap();
        let s = store.stats();
        assert_eq!(s.records, 1);
        assert!(s.bytes > 0);
        assert_eq!(store.get(key).unwrap(), rec(42));
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn counters_track_hits_and_misses() {
        let dir = scratch("counters");
        let store = DesignPointStore::open(&dir).unwrap();
        let key = KeyBuilder::new("unit/1").u64(1).finish();
        let (_, hit) = store.get_or_put_with(key, || rec(1));
        assert!(!hit);
        let (r, hit) = store.get_or_put_with(key, || panic!("must not recompute"));
        assert!(hit);
        assert_eq!(r, rec(1));
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.writes), (1, 1, 1));
        assert_eq!(s.hit_rate(), 0.5);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn gc_evicts_oldest_first_to_budget() {
        let dir = scratch("gc");
        let store = DesignPointStore::open(&dir).unwrap();
        let keys: Vec<Key128> = (0..8)
            .map(|i| {
                let k = KeyBuilder::new("unit/1").u64(i).finish();
                store.put(k, &rec(i)).unwrap();
                // Distinct mtimes so eviction order is by age.
                std::thread::sleep(std::time::Duration::from_millis(2));
                k
            })
            .collect();
        let before = store.stats();
        assert_eq!(before.records, 8);
        let per_rec = before.bytes / 8;
        let evicted = store.gc(per_rec * 3);
        assert_eq!(evicted, 5);
        let after = store.stats();
        assert_eq!(after.records, 3);
        assert!(after.bytes <= per_rec * 3);
        // The newest records survive.
        for k in &keys[5..] {
            assert!(store.get(*k).is_some());
        }
        for k in &keys[..5] {
            assert!(store.get(*k).is_none());
        }
        assert_eq!(store.gc(u64::MAX), 0);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn verify_reports_and_repairs() {
        let dir = scratch("verify");
        let store = DesignPointStore::open(&dir).unwrap();
        let k1 = KeyBuilder::new("unit/1").u64(1).finish();
        let k2 = KeyBuilder::new("unit/1").u64(2).finish();
        store.put(k1, &rec(1)).unwrap();
        store.put(k2, &rec(2)).unwrap();
        // Corrupt k2 on disk.
        let path = store.path_for(k2);
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        fs::write(&path, &bytes).unwrap();
        let report = store.verify(false);
        assert_eq!((report.checked, report.ok), (2, 1));
        assert_eq!(report.corrupt, vec![path.clone()]);
        assert!(path.exists());
        let report = store.verify(true);
        assert_eq!(report.corrupt.len(), 1);
        assert!(!path.exists());
        assert_eq!(store.stats().records, 1);
        fs::remove_dir_all(&dir).unwrap();
    }
}
