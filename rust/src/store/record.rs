//! Versioned binary (de)serialization of design-point records.
//!
//! On-disk layout of one `.dpr` file:
//!
//! ```text
//! magic     8 B   "OACMDPR\0"
//! version   4 B   FORMAT_VERSION (LE) — mismatches are treated as a miss
//! key      16 B   the content hash the record was stored under
//! length    8 B   payload byte count
//! payload   N B   the record body (length-prefixed, tag-prefixed fields)
//! checksum  8 B   checksum64 over everything above
//! ```
//!
//! The checksum footer plus atomic rename-on-write means a torn, truncated
//! or bit-flipped record is *detected and recomputed*, never trusted; a
//! [`FORMAT_VERSION`] bump invalidates every existing record at once (old
//! files are reclaimed by GC). All integers little-endian; floats stored as
//! their exact bit patterns, so a cache round-trip is bit-identical.

use anyhow::{bail, Result};

use super::key::{checksum64, Key128};
use super::wire::{put_f64, put_str, put_u32, put_u64, Reader};
use crate::mult::error_metrics::ErrorReport;
use crate::ppa::report::MacroPpa;
use crate::sim::activity::ActivityReport;
use crate::yield_analysis::mc::McResult;

pub const MAGIC: &[u8; 8] = b"OACMDPR\0";
/// v2: added the calibration-accuracy section (the compile pass's
/// memoized per-assignment top-1 measurements). Every v1 record fails
/// validation, reads as a miss and is recomputed — the documented
/// whole-store invalidation path.
pub const FORMAT_VERSION: u32 = 2;

/// Error-metric section (mirrors [`ErrorReport`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ErrorStats {
    pub nmed: f64,
    pub mred: f64,
    pub error_rate: f64,
    pub wce: u64,
    pub normalized_bias: f64,
    pub samples: u64,
}

impl ErrorStats {
    pub fn from_report(r: &ErrorReport) -> ErrorStats {
        ErrorStats {
            nmed: r.nmed,
            mred: r.mred,
            error_rate: r.error_rate,
            wce: r.wce,
            normalized_bias: r.normalized_bias,
            samples: r.samples,
        }
    }

    pub fn to_report(self) -> ErrorReport {
        ErrorReport {
            nmed: self.nmed,
            mred: self.mred,
            error_rate: self.error_rate,
            wce: self.wce,
            normalized_bias: self.normalized_bias,
            samples: self.samples,
        }
    }
}

/// PPA section (the numeric core of [`MacroPpa`]; instance name and family
/// label are reattached from the spec on the way out, so two specs naming
/// the same structure share one record).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PpaSummary {
    pub delay_ns: f64,
    pub logic_area_um2: f64,
    pub sram_area_um2: f64,
    pub pnr_area_um2: f64,
    pub power_w: f64,
    pub energy_per_op_j: f64,
    pub logic_power_w: f64,
    pub mult_gates: u64,
}

impl PpaSummary {
    pub fn from_ppa(p: &MacroPpa) -> PpaSummary {
        PpaSummary {
            delay_ns: p.delay_ns,
            logic_area_um2: p.logic_area_um2,
            sram_area_um2: p.sram_area_um2,
            pnr_area_um2: p.pnr_area_um2,
            power_w: p.power_w,
            energy_per_op_j: p.energy_per_op_j,
            logic_power_w: p.logic_power_w,
            mult_gates: p.mult_gates as u64,
        }
    }

    pub fn to_ppa(self, name: &str, family_label: &str) -> MacroPpa {
        MacroPpa {
            name: name.to_string(),
            family_label: family_label.to_string(),
            delay_ns: self.delay_ns,
            logic_area_um2: self.logic_area_um2,
            sram_area_um2: self.sram_area_um2,
            pnr_area_um2: self.pnr_area_um2,
            power_w: self.power_w,
            energy_per_op_j: self.energy_per_op_j,
            logic_power_w: self.logic_power_w,
            mult_gates: self.mult_gates as usize,
        }
    }
}

/// Per-net toggle activity section (mirrors [`ActivityReport`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ActivityStats {
    pub toggles: Vec<u64>,
    pub transitions: u64,
}

impl ActivityStats {
    pub fn from_report(r: &ActivityReport) -> ActivityStats {
        ActivityStats {
            toggles: r.toggles.clone(),
            transitions: r.transitions,
        }
    }

    pub fn to_report(&self) -> ActivityReport {
        ActivityReport {
            toggles: self.toggles.clone(),
            transitions: self.transitions,
        }
    }
}

/// Functional-yield section (mirrors [`McResult`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct YieldStats {
    pub pf: f64,
    pub fom: f64,
    pub sims: u64,
    pub failures: u64,
}

impl YieldStats {
    pub fn from_mc(r: &McResult) -> YieldStats {
        YieldStats {
            pf: r.pf,
            fom: r.fom,
            sims: r.sims,
            failures: r.failures,
        }
    }

    pub fn to_mc(self) -> McResult {
        McResult {
            pf: self.pf,
            fom: self.fom,
            sims: self.sims,
            failures: self.failures,
        }
    }
}

/// Calibration-accuracy section: one compile-pass measurement of a
/// heterogeneous per-layer multiplier assignment's top-1 accuracy on a
/// calibration set. The assignment, model and calibration set are all in
/// the *key* (`"compile-accuracy/1"` domain); the record only carries the
/// measured result.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AccuracyStats {
    /// Measured top-1 accuracy on the calibration set, in [0, 1].
    pub top1: f64,
    /// Calibration-set size the measurement used.
    pub samples: u64,
}

/// One persistent characterization record. Sections are optional so the
/// error-metric, PPA/activity, functional-yield and compile-accuracy
/// producers all flow through the same type (and file format) while only
/// paying for what they computed.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DesignPointRecord {
    /// Family descriptor (e.g. `appro42[yang1x8]`) — metadata for `store
    /// stats` and warm-start matching, *not* part of the key.
    pub family: String,
    pub bits: u32,
    pub rows: u32,
    /// Workload size the dynamic sections were characterized with.
    pub n_ops: u64,
    pub seed: u64,
    pub error: Option<ErrorStats>,
    pub ppa: Option<PpaSummary>,
    pub activity: Option<ActivityStats>,
    pub fyield: Option<YieldStats>,
    pub accuracy: Option<AccuracyStats>,
}

impl DesignPointRecord {
    /// Serialize with header + checksum footer, ready for atomic write.
    pub fn encode(&self, key: Key128) -> Vec<u8> {
        let toggle_count = self.activity.as_ref().map_or(0, |a| a.toggles.len());
        let mut payload = Vec::with_capacity(128 + 8 * toggle_count);
        put_str(&mut payload, &self.family);
        put_u32(&mut payload, self.bits);
        put_u32(&mut payload, self.rows);
        put_u64(&mut payload, self.n_ops);
        put_u64(&mut payload, self.seed);
        match &self.error {
            None => payload.push(0),
            Some(e) => {
                payload.push(1);
                put_f64(&mut payload, e.nmed);
                put_f64(&mut payload, e.mred);
                put_f64(&mut payload, e.error_rate);
                put_u64(&mut payload, e.wce);
                put_f64(&mut payload, e.normalized_bias);
                put_u64(&mut payload, e.samples);
            }
        }
        match &self.ppa {
            None => payload.push(0),
            Some(p) => {
                payload.push(1);
                put_f64(&mut payload, p.delay_ns);
                put_f64(&mut payload, p.logic_area_um2);
                put_f64(&mut payload, p.sram_area_um2);
                put_f64(&mut payload, p.pnr_area_um2);
                put_f64(&mut payload, p.power_w);
                put_f64(&mut payload, p.energy_per_op_j);
                put_f64(&mut payload, p.logic_power_w);
                put_u64(&mut payload, p.mult_gates);
            }
        }
        match &self.activity {
            None => payload.push(0),
            Some(a) => {
                payload.push(1);
                put_u64(&mut payload, a.transitions);
                put_u32(&mut payload, a.toggles.len() as u32);
                for &t in &a.toggles {
                    put_u64(&mut payload, t);
                }
            }
        }
        match &self.fyield {
            None => payload.push(0),
            Some(y) => {
                payload.push(1);
                put_f64(&mut payload, y.pf);
                put_f64(&mut payload, y.fom);
                put_u64(&mut payload, y.sims);
                put_u64(&mut payload, y.failures);
            }
        }
        match &self.accuracy {
            None => payload.push(0),
            Some(a) => {
                payload.push(1);
                put_f64(&mut payload, a.top1);
                put_u64(&mut payload, a.samples);
            }
        }

        let mut out = Vec::with_capacity(44 + payload.len());
        out.extend_from_slice(MAGIC);
        put_u32(&mut out, FORMAT_VERSION);
        out.extend_from_slice(&key.0.to_le_bytes());
        put_u64(&mut out, payload.len() as u64);
        out.extend_from_slice(&payload);
        let sum = checksum64(&out);
        put_u64(&mut out, sum);
        out
    }

    /// Decode and fully validate one record image. Every failure mode —
    /// short file, bad magic, version skew, truncated payload, checksum
    /// mismatch, key mismatch — is an `Err`, which the store maps to a
    /// *miss* (recompute), never to garbage data.
    pub fn decode(bytes: &[u8], expect_key: Option<Key128>) -> Result<(Key128, DesignPointRecord)> {
        if bytes.len() < 44 {
            bail!("record too short: {} bytes", bytes.len());
        }
        if &bytes[..8] != MAGIC {
            bail!("bad magic");
        }
        let body = &bytes[..bytes.len() - 8];
        let sum = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        if checksum64(body) != sum {
            bail!("checksum mismatch (torn or corrupted record)");
        }
        let mut r = Reader { buf: body, pos: 8 };
        let version = r.u32()?;
        if version != FORMAT_VERSION {
            bail!("format version {version} != {FORMAT_VERSION}");
        }
        let key = Key128(u128::from_le_bytes(r.take(16)?.try_into().unwrap()));
        if let Some(k) = expect_key {
            if k != key {
                bail!("key mismatch: file holds {}", key.hex());
            }
        }
        let payload_len = r.u64()? as usize;
        if r.buf.len() - r.pos != payload_len {
            bail!(
                "payload length {} != header claim {payload_len}",
                r.buf.len() - r.pos
            );
        }
        let family = r.str()?;
        let bits = r.u32()?;
        let rows = r.u32()?;
        let n_ops = r.u64()?;
        let seed = r.u64()?;
        let error = if r.u8()? == 1 {
            Some(ErrorStats {
                nmed: r.f64()?,
                mred: r.f64()?,
                error_rate: r.f64()?,
                wce: r.u64()?,
                normalized_bias: r.f64()?,
                samples: r.u64()?,
            })
        } else {
            None
        };
        let ppa = if r.u8()? == 1 {
            Some(PpaSummary {
                delay_ns: r.f64()?,
                logic_area_um2: r.f64()?,
                sram_area_um2: r.f64()?,
                pnr_area_um2: r.f64()?,
                power_w: r.f64()?,
                energy_per_op_j: r.f64()?,
                logic_power_w: r.f64()?,
                mult_gates: r.u64()?,
            })
        } else {
            None
        };
        let activity = if r.u8()? == 1 {
            let transitions = r.u64()?;
            let n = r.u32()? as usize;
            if n > (r.buf.len() - r.pos) / 8 {
                bail!("activity length {n} exceeds remaining payload");
            }
            let mut toggles = Vec::with_capacity(n);
            for _ in 0..n {
                toggles.push(r.u64()?);
            }
            Some(ActivityStats { toggles, transitions })
        } else {
            None
        };
        let fyield = if r.u8()? == 1 {
            Some(YieldStats {
                pf: r.f64()?,
                fom: r.f64()?,
                sims: r.u64()?,
                failures: r.u64()?,
            })
        } else {
            None
        };
        let accuracy = if r.u8()? == 1 {
            Some(AccuracyStats {
                top1: r.f64()?,
                samples: r.u64()?,
            })
        } else {
            None
        };
        if r.pos != r.buf.len() {
            bail!("{} trailing payload bytes", r.buf.len() - r.pos);
        }
        Ok((
            key,
            DesignPointRecord {
                family,
                bits,
                rows,
                n_ops,
                seed,
                error,
                ppa,
                activity,
                fyield,
                accuracy,
            },
        ))
    }
}

// Wire helpers (`put_*`, `Reader`) live in `super::wire`, shared with the
// compiled-plan artifact format.

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DesignPointRecord {
        DesignPointRecord {
            family: "appro42[yang1x8]".into(),
            bits: 8,
            rows: 16,
            n_ops: 1500,
            seed: 0xD5E,
            error: Some(ErrorStats {
                nmed: 2.82e-4,
                mred: 1.1e-3,
                error_rate: 0.47,
                wce: 1234,
                normalized_bias: -2.7e-4,
                samples: 65536,
            }),
            ppa: Some(PpaSummary {
                delay_ns: 5.2,
                logic_area_um2: 812.0,
                sram_area_um2: 300.5,
                pnr_area_um2: 1112.5,
                power_w: 2.1e-4,
                energy_per_op_j: 2.1e-12,
                logic_power_w: 1.4e-4,
                mult_gates: 431,
            }),
            activity: Some(ActivityStats {
                toggles: (0..64u64).map(|i| i * 17).collect(),
                transitions: 1499,
            }),
            fyield: Some(YieldStats {
                pf: 0.015625,
                fom: 0.9,
                sims: 640,
                failures: 10,
            }),
            accuracy: Some(AccuracyStats {
                top1: 0.96875,
                samples: 256,
            }),
        }
    }

    #[test]
    fn roundtrip_bit_identical() {
        let rec = sample();
        let key = Key128(0xABCD_EF01_2345_6789_9876_5432_10FE_DCBA);
        let bytes = rec.encode(key);
        let (k, back) = DesignPointRecord::decode(&bytes, Some(key)).unwrap();
        assert_eq!(k, key);
        assert_eq!(back, rec);
        // f64 round-trip is bit-exact, not approximately-equal.
        assert_eq!(
            back.error.unwrap().nmed.to_bits(),
            rec.error.unwrap().nmed.to_bits()
        );
    }

    #[test]
    fn empty_sections_roundtrip() {
        let rec = DesignPointRecord {
            family: "exact".into(),
            bits: 6,
            ..Default::default()
        };
        let key = Key128(7);
        let (_, back) = DesignPointRecord::decode(&rec.encode(key), Some(key)).unwrap();
        assert_eq!(back, rec);
        assert!(back.error.is_none() && back.ppa.is_none());
    }

    #[test]
    fn truncation_detected() {
        let bytes = sample().encode(Key128(9));
        for cut in [0, 10, 43, bytes.len() - 9, bytes.len() - 1] {
            assert!(
                DesignPointRecord::decode(&bytes[..cut], Some(Key128(9))).is_err(),
                "cut at {cut} must fail"
            );
        }
    }

    #[test]
    fn every_single_bit_flip_detected() {
        let bytes = sample().encode(Key128(3));
        // Flipping any single bit anywhere must be caught (checksum, magic
        // or structural validation).
        for byte in (0..bytes.len()).step_by(7) {
            for bit in 0..8 {
                let mut b = bytes.clone();
                b[byte] ^= 1 << bit;
                assert!(
                    DesignPointRecord::decode(&b, Some(Key128(3))).is_err(),
                    "flip at {byte}.{bit} undetected"
                );
            }
        }
    }

    #[test]
    fn key_and_version_skew_rejected() {
        let bytes = sample().encode(Key128(5));
        assert!(DesignPointRecord::decode(&bytes, Some(Key128(6))).is_err());
        // Decoding under no expectation still returns the stored key.
        let (k, _) = DesignPointRecord::decode(&bytes, None).unwrap();
        assert_eq!(k, Key128(5));
    }
}
