//! Shared little-endian wire primitives for on-disk artifacts.
//!
//! Both binary formats this crate writes — design-point records
//! (`store::record`, `.dpr`) and compiled plans (`compile::plan`,
//! `.acmplan`) — use the same conventions: integers little-endian, floats
//! as exact `f64` bit patterns, strings length-prefixed. One
//! implementation serves both so a bounds-check or encoding fix can never
//! drift between the formats.

use anyhow::{bail, Result};

pub(crate) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

pub(crate) fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// Bounds-checked sequential reader over one artifact image. Every read
/// past the end is an `Err` (the decoders map it to "refuse the file"),
/// never a panic or garbage.
pub(crate) struct Reader<'a> {
    pub(crate) buf: &'a [u8],
    pub(crate) pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!("artifact truncated at byte {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub(crate) fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8_lossy(self.take(n)?).into_owned())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_bounds() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 7);
        put_u64(&mut buf, u64::MAX);
        put_f64(&mut buf, -0.5);
        put_str(&mut buf, "hi");
        let mut r = Reader { buf: &buf, pos: 0 };
        assert_eq!(r.u32().unwrap(), 7);
        assert_eq!(r.u64().unwrap(), u64::MAX);
        assert_eq!(r.f64().unwrap().to_bits(), (-0.5f64).to_bits());
        assert_eq!(r.str().unwrap(), "hi");
        assert_eq!(r.pos, buf.len());
        assert!(r.u8().is_err(), "reading past the end must fail");
    }
}
