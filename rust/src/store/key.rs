//! Content-addressed keys for design-point records.
//!
//! A [`Key128`] is derived from a *canonical byte encoding* of everything
//! that determines a characterization result: the netlist structure (via
//! [`crate::gates::Netlist::canonical_bytes`] — gate kinds, connectivity
//! and port declarations, but *not* instance names or debug net names) plus
//! the characterization parameters (bit width, workload size, seed, SRAM
//! geometry, …), all folded through MurmurHash3 x64-128. Every key domain
//! starts with a tag string (`"error-exhaustive/1"`, `"ppa/1"`, …) so
//! records of different kinds can never collide, and bumping the tag
//! version invalidates exactly that domain.
//!
//! The hash is seeded with a fixed constant — keys are stable across runs,
//! processes and machines, which is what makes the on-disk store shareable.

use crate::gates::Netlist;

/// A stable 128-bit content hash.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Key128(pub u128);

impl Key128 {
    /// 32-hex-digit file stem.
    pub fn hex(&self) -> String {
        format!("{:032x}", self.0)
    }

    /// Parse a 32-hex-digit stem back into a key (used when scanning the
    /// on-disk layout into the index).
    pub fn from_hex(s: &str) -> Option<Key128> {
        if s.len() != 32 {
            return None;
        }
        u128::from_str_radix(s, 16).ok().map(Key128)
    }

    /// Shard selector: the top byte of the key (matches the two-hex-digit
    /// directory fan-out on disk).
    pub fn shard_byte(&self) -> u8 {
        (self.0 >> 120) as u8
    }
}

/// Canonical encoder: accumulates fields into a byte buffer, then hashes
/// the whole buffer. Scalars are raw little-endian (NOT self-describing);
/// strings and lists are length-prefixed. Collision-freedom therefore
/// rests on each domain tag implying one fixed field sequence — a domain
/// must never encode conditionally-present scalars (wrap variability in a
/// length-prefixed list or add an explicit presence byte instead).
/// Encoding before hashing keeps the canonical form trivially auditable.
pub struct KeyBuilder {
    buf: Vec<u8>,
}

impl KeyBuilder {
    /// `domain` tags the record kind *and* its schema version; change it to
    /// invalidate all keys of one kind.
    pub fn new(domain: &str) -> KeyBuilder {
        let mut b = KeyBuilder { buf: Vec::with_capacity(256) };
        b.str(domain);
        b
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    /// Floats are keyed by their exact bit pattern — two runs agree on a
    /// key iff they agree on the parameter to the last ulp.
    pub fn f64(&mut self, v: f64) -> &mut Self {
        self.u64(v.to_bits())
    }

    pub fn str(&mut self, s: &str) -> &mut Self {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
        self
    }

    /// Length-prefixed raw bytes (e.g. quantized weight tensors, a
    /// calibration image set) — the content-addressing primitive behind
    /// the compile pass's model/calibration hashes.
    pub fn bytes(&mut self, bs: &[u8]) -> &mut Self {
        self.u32(bs.len() as u32);
        self.buf.extend_from_slice(bs);
        self
    }

    /// Fold a previously computed key in (hash composition: e.g. the
    /// compile pass keys on `model hash × assignment × calibration hash`
    /// without re-hashing the underlying tensors).
    pub fn key(&mut self, k: Key128) -> &mut Self {
        self.buf.extend_from_slice(&k.0.to_le_bytes());
        self
    }

    pub fn f64s(&mut self, vs: &[f64]) -> &mut Self {
        self.u32(vs.len() as u32);
        for &v in vs {
            self.f64(v);
        }
        self
    }

    pub fn pairs(&mut self, vs: &[(u64, u64)]) -> &mut Self {
        self.u32(vs.len() as u32);
        for &(a, b) in vs {
            self.u64(a).u64(b);
        }
        self
    }

    /// Fold in the canonical structural form of a netlist.
    pub fn netlist(&mut self, nl: &Netlist) -> &mut Self {
        nl.canonical_bytes(&mut self.buf);
        self
    }

    pub fn finish(&self) -> Key128 {
        let (h1, h2) = murmur3_x64_128(&self.buf, 0x0ACA_CE11);
        Key128(((h1 as u128) << 64) | h2 as u128)
    }
}

/// 64-bit content checksum (the record footer) — the low half of the same
/// 128-bit hash, under a distinct seed from key derivation.
pub fn checksum64(data: &[u8]) -> u64 {
    murmur3_x64_128(data, 0xC0DE_F00D).1
}

#[inline]
fn fmix64(mut k: u64) -> u64 {
    k ^= k >> 33;
    k = k.wrapping_mul(0xff51afd7ed558ccd);
    k ^= k >> 33;
    k = k.wrapping_mul(0xc4ceb9fe1a85ec53);
    k ^= k >> 33;
    k
}

/// Reference MurmurHash3 x64-128 (Appleby, public domain algorithm).
fn murmur3_x64_128(data: &[u8], seed: u64) -> (u64, u64) {
    const C1: u64 = 0x87c37b91114253d5;
    const C2: u64 = 0x4cf5ad432745937f;
    let mut h1 = seed;
    let mut h2 = seed;
    let mut chunks = data.chunks_exact(16);
    for block in &mut chunks {
        let mut k1 = u64::from_le_bytes(block[0..8].try_into().unwrap());
        let mut k2 = u64::from_le_bytes(block[8..16].try_into().unwrap());
        k1 = k1.wrapping_mul(C1).rotate_left(31).wrapping_mul(C2);
        h1 ^= k1;
        h1 = h1
            .rotate_left(27)
            .wrapping_add(h2)
            .wrapping_mul(5)
            .wrapping_add(0x52dce729);
        k2 = k2.wrapping_mul(C2).rotate_left(33).wrapping_mul(C1);
        h2 ^= k2;
        h2 = h2
            .rotate_left(31)
            .wrapping_add(h1)
            .wrapping_mul(5)
            .wrapping_add(0x38495ab5);
    }
    let tail = chunks.remainder();
    if !tail.is_empty() {
        let mut k1 = 0u64;
        let mut k2 = 0u64;
        for (i, &b) in tail.iter().enumerate() {
            if i < 8 {
                k1 |= (b as u64) << (8 * i);
            } else {
                k2 |= (b as u64) << (8 * (i - 8));
            }
        }
        k2 = k2.wrapping_mul(C2).rotate_left(33).wrapping_mul(C1);
        h2 ^= k2;
        k1 = k1.wrapping_mul(C1).rotate_left(31).wrapping_mul(C2);
        h1 ^= k1;
    }
    let len = data.len() as u64;
    h1 ^= len;
    h2 ^= len;
    h1 = h1.wrapping_add(h2);
    h2 = h2.wrapping_add(h1);
    h1 = fmix64(h1);
    h2 = fmix64(h2);
    h1 = h1.wrapping_add(h2);
    h2 = h2.wrapping_add(h1);
    (h1, h2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::{CompressorKind, MultFamily, MultSpec};

    fn netlist(family: MultFamily, bits: usize) -> Netlist {
        crate::mult::build_netlist(&MultSpec {
            family,
            bits,
            signed: false,
        })
    }

    #[test]
    fn hex_roundtrip() {
        let k = Key128(0x0123_4567_89ab_cdef_fedc_ba98_7654_3210);
        assert_eq!(Key128::from_hex(&k.hex()), Some(k));
        assert_eq!(Key128::from_hex("zz"), None);
        assert_eq!(k.shard_byte(), 0x01);
    }

    #[test]
    fn keys_stable_across_builders() {
        let nl = netlist(MultFamily::Exact, 6);
        let a = KeyBuilder::new("t/1").netlist(&nl).u32(6).finish();
        let b = KeyBuilder::new("t/1").netlist(&nl).u32(6).finish();
        assert_eq!(a, b);
    }

    #[test]
    fn domain_and_params_separate_keys() {
        let nl = netlist(MultFamily::Exact, 6);
        let a = KeyBuilder::new("t/1").netlist(&nl).u32(6).finish();
        let b = KeyBuilder::new("t/2").netlist(&nl).u32(6).finish();
        let c = KeyBuilder::new("t/1").netlist(&nl).u32(7).finish();
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn structurally_different_netlists_differ() {
        let exact = netlist(MultFamily::Exact, 6);
        let approx = netlist(
            MultFamily::Approx42 {
                compressor: CompressorKind::Yang1,
                approx_cols: 6,
            },
            6,
        );
        let a = KeyBuilder::new("t/1").netlist(&exact).finish();
        let b = KeyBuilder::new("t/1").netlist(&approx).finish();
        assert_ne!(a, b);
    }

    #[test]
    fn instance_name_does_not_affect_key() {
        // Content addressing: the same circuit under two instance names
        // (e.g. "dse_exact" vs "ppa_exact") must share one record.
        let mut a = netlist(MultFamily::Exact, 6);
        let mut b = netlist(MultFamily::Exact, 6);
        a.name = "one".into();
        b.name = "two".into();
        let ka = KeyBuilder::new("t/1").netlist(&a).finish();
        let kb = KeyBuilder::new("t/1").netlist(&b).finish();
        assert_eq!(ka, kb);
    }

    #[test]
    fn murmur_reference_vectors() {
        // Self-consistency + avalanche sanity (a one-bit input change flips
        // roughly half the output bits).
        let (a1, a2) = murmur3_x64_128(b"hello, world", 0);
        let (b1, b2) = murmur3_x64_128(b"hello, worle", 0);
        assert_ne!((a1, a2), (b1, b2));
        let flipped = ((a1 ^ b1).count_ones() + (a2 ^ b2).count_ones()) as i32;
        assert!((32..=96).contains(&flipped), "poor avalanche: {flipped}");
        // Block + tail path both exercised for every length 0..48.
        let data: Vec<u8> = (0..48u8).collect();
        let mut seen = std::collections::BTreeSet::new();
        for len in 0..=48 {
            assert!(seen.insert(murmur3_x64_128(&data[..len], 7)));
        }
    }

    #[test]
    fn bytes_are_length_prefixed_and_composable() {
        // Length prefixing: ("ab","c") and ("a","bc") must not collide.
        let a = KeyBuilder::new("t/1").bytes(b"ab").bytes(b"c").finish();
        let b = KeyBuilder::new("t/1").bytes(b"a").bytes(b"bc").finish();
        assert_ne!(a, b);
        // Key composition is deterministic and order-sensitive.
        let inner = KeyBuilder::new("inner/1").u64(7).finish();
        let c = KeyBuilder::new("t/1").key(inner).u64(1).finish();
        let d = KeyBuilder::new("t/1").key(inner).u64(1).finish();
        let e = KeyBuilder::new("t/1").u64(1).key(inner).finish();
        assert_eq!(c, d);
        assert_ne!(c, e);
    }

    #[test]
    fn checksum_differs_from_key_hash() {
        let k = KeyBuilder::new("x").finish();
        assert_ne!(checksum64(b"x"), k.0 as u64);
    }
}
