//! `openacm store` — inspect and maintain the design-point store.
//!
//! * `openacm store stats [--dir D] [--json]` — record counts, footprint,
//!   and a per-family / per-section breakdown (`--json` emits a
//!   machine-readable document for CI and benches);
//! * `openacm store verify [--dir D] [--repair]` — full integrity scan
//!   (checksums, format version); `--repair` deletes corrupt records so
//!   the next access recomputes them;
//! * `openacm store gc [--dir D] [--max-mb N]` — size-bounded, oldest-first
//!   eviction.

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;

use super::DesignPointStore;
use crate::bench::harness::Table;
use crate::util::cli::Args;

/// Shared CLI resolution for store-backed commands: `--no-cache` disables
/// the store entirely, `--store DIR` overrides the default root. An
/// explicitly requested store that cannot be opened is a hard error; an
/// unusable *default* store (read-only checkout, unwritable CWD) degrades
/// to uncached operation with a warning — the sweep itself has no
/// filesystem dependency and must keep working.
pub fn store_from_args(args: &Args) -> Result<Option<DesignPointStore>> {
    if args.flag("no-cache") {
        return Ok(None);
    }
    match args.get("store") {
        Some(dir) => Ok(Some(DesignPointStore::open(&PathBuf::from(dir))?)),
        None => match DesignPointStore::open(&DesignPointStore::default_dir()) {
            Ok(store) => Ok(Some(store)),
            Err(e) => {
                eprintln!("design-point store unavailable ({e:#}); running uncached");
                Ok(None)
            }
        },
    }
}

pub fn cmd_store(args: &Args) -> Result<()> {
    let dir = args
        .get("dir")
        .map(PathBuf::from)
        .unwrap_or_else(DesignPointStore::default_dir);
    let action = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("stats");
    let store = DesignPointStore::open(&dir)?;
    match action {
        "stats" => cmd_stats(&store, args.flag("json")),
        "verify" => cmd_verify(&store, args.flag("repair")),
        "gc" => {
            let max_mb = args.f64_or("max-mb", 256.0)?;
            if max_mb < 0.0 {
                bail!("--max-mb must be non-negative");
            }
            let evicted = store.gc((max_mb * 1e6) as u64);
            let s = store.stats();
            println!(
                "gc: evicted {evicted} records; {} records / {:.2} MB remain (budget {max_mb} MB)",
                s.records,
                s.bytes as f64 / 1e6
            );
            Ok(())
        }
        other => bail!("unknown store action {other:?}; expected stats|verify|gc"),
    }
}

fn cmd_stats(store: &DesignPointStore, json: bool) -> Result<()> {
    #[derive(Default)]
    struct FamilyAgg {
        records: u64,
        error: u64,
        ppa: u64,
        activity: u64,
        fyield: u64,
        accuracy: u64,
    }
    let mut by_family: BTreeMap<String, FamilyAgg> = BTreeMap::new();
    store.for_each_record(|_, rec| {
        let f = by_family.entry(rec.family.clone()).or_default();
        f.records += 1;
        f.error += rec.error.is_some() as u64;
        f.ppa += rec.ppa.is_some() as u64;
        f.activity += rec.activity.is_some() as u64;
        f.fyield += rec.fyield.is_some() as u64;
        f.accuracy += rec.accuracy.is_some() as u64;
    });
    let s = store.stats();
    if json {
        // Hand-rolled (offline build, no serde) — same convention as
        // BenchJson / obs snapshots. Family names are \"-escaped.
        let esc = |t: &str| t.replace('\\', "\\\\").replace('"', "\\\"");
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"root\": \"{}\",\n", esc(&store.root().display().to_string())));
        out.push_str(&format!("  \"format_version\": {},\n", super::FORMAT_VERSION));
        out.push_str(&format!(
            "  \"records\": {}, \"bytes\": {}, \"hits\": {}, \"misses\": {}, \
             \"writes\": {}, \"evictions\": {}, \"corrupt\": {},\n",
            s.records, s.bytes, s.hits, s.misses, s.writes, s.evictions, s.corrupt
        ));
        out.push_str("  \"families\": [");
        for (i, (family, agg)) in by_family.iter().enumerate() {
            out.push_str(if i == 0 { "\n" } else { ",\n" });
            out.push_str(&format!(
                "    {{\"family\": \"{}\", \"records\": {}, \"error\": {}, \"ppa\": {}, \
                 \"activity\": {}, \"yield\": {}, \"accuracy\": {}}}",
                esc(family),
                agg.records,
                agg.error,
                agg.ppa,
                agg.activity,
                agg.fyield,
                agg.accuracy
            ));
        }
        out.push_str("\n  ]\n}\n");
        print!("{out}");
        return Ok(());
    }
    println!(
        "store {}: {} records, {:.2} MB (format v{})",
        store.root().display(),
        s.records,
        s.bytes as f64 / 1e6,
        super::FORMAT_VERSION
    );
    let mut t = Table::new(
        "records by family",
        &["Family", "Records", "Error", "PPA", "Activity", "Yield", "Accuracy"],
    );
    for (family, agg) in &by_family {
        t.row(&[
            family.clone(),
            agg.records.to_string(),
            agg.error.to_string(),
            agg.ppa.to_string(),
            agg.activity.to_string(),
            agg.fyield.to_string(),
            agg.accuracy.to_string(),
        ]);
    }
    if by_family.is_empty() {
        println!("(empty — run `openacm dse` or `openacm ppa` to populate)");
    } else {
        t.print();
    }
    Ok(())
}

fn cmd_verify(store: &DesignPointStore, repair: bool) -> Result<()> {
    let report = store.verify(repair);
    println!(
        "verify {}: {} checked, {} ok, {} corrupt{}",
        store.root().display(),
        report.checked,
        report.ok,
        report.corrupt.len(),
        if repair && !report.corrupt.is_empty() {
            " (removed)"
        } else {
            ""
        }
    );
    for p in &report.corrupt {
        println!("  corrupt: {}", p.display());
    }
    if !report.corrupt.is_empty() && !repair {
        println!("re-run with --repair to delete corrupt records");
    }
    Ok(())
}
