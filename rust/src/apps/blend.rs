//! Image blending (paper §V-B): an 8-bit unsigned multiplier processes two
//! grayscale images pixel by pixel — `out = (a × b) >> 8` — exactly the
//! multiplicative blend of [27], with results scaled back to 8 bits.

use super::images::Image;
use crate::config::spec::MultFamily;
use crate::mult::behavioral::behavioral_fn;

/// Blend two equal-size images through a multiplier family.
pub fn blend(a: &Image, b: &Image, family: &MultFamily) -> Image {
    assert_eq!((a.w, a.h), (b.w, b.h), "blend needs equal sizes");
    let f = behavioral_fn(family, 8);
    let mut out = Image::new(a.w, a.h);
    for i in 0..a.px.len() {
        let p = f(a.px[i] as u64, b.px[i] as u64);
        out.px[i] = (p >> 8).min(255) as u8;
    }
    out
}

/// Blend via a precomputed 65536-entry LUT (the hot path used by the
/// serving coordinator; must agree with [`blend`] bit-for-bit).
pub fn blend_lut(a: &Image, b: &Image, lut: &[i32]) -> Image {
    assert_eq!(lut.len(), 65536);
    let mut out = Image::new(a.w, a.h);
    for i in 0..a.px.len() {
        let p = lut[((a.px[i] as usize) << 8) | b.px[i] as usize];
        out.px[i] = ((p as u32) >> 8).min(255) as u8;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::images;
    use crate::mult::behavioral::uint8_lut;

    #[test]
    fn exact_blend_matches_reference_math() {
        let a = images::lake(32);
        let b = images::mandril(32);
        let out = blend(&a, &b, &MultFamily::Exact);
        for i in 0..out.px.len() {
            assert_eq!(
                out.px[i] as u64,
                (a.px[i] as u64 * b.px[i] as u64) >> 8
            );
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn lut_blend_matches_behavioral_blend() {
        let a = images::boat(48);
        let b = images::cameraman(48);
        for fam in [MultFamily::LogOur, MultFamily::Mitchell] {
            let lut = uint8_lut(&fam);
            assert_eq!(blend(&a, &b, &fam), blend_lut(&a, &b, &lut), "{fam:?}");
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn approximate_blend_is_close_to_exact() {
        let a = images::lake(64);
        let b = images::boat(64);
        let exact = blend(&a, &b, &MultFamily::Exact);
        let appro = blend(&a, &b, &MultFamily::default_approx(8));
        let mut max_d = 0i32;
        for i in 0..exact.px.len() {
            max_d = max_d.max((exact.px[i] as i32 - appro.px[i] as i32).abs());
        }
        assert!(max_d <= 4, "appro4-2 blend deviates by {max_d} levels");
    }
}
