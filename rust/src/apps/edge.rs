//! Sobel edge detection (paper §V-B): convolution and squaring use a
//! 16-bit *signed* approximate multiplier (sign-magnitude wrapped), the
//! final square root is computed exactly — exactly the paper's protocol.

use super::images::Image;
use crate::config::spec::MultFamily;
use crate::mult::behavioral::{behavioral_fn, signed_multiply};

const SOBEL_X: [[i64; 3]; 3] = [[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]];
const SOBEL_Y: [[i64; 3]; 3] = [[-1, -2, -1], [0, 0, 0], [1, 2, 1]];

/// Integer square root (exact, per the paper: "the square root is computed
/// exactly").
pub fn isqrt(v: u64) -> u64 {
    if v == 0 {
        return 0;
    }
    let mut x = (v as f64).sqrt() as u64;
    // Fix up float rounding.
    while (x + 1) * (x + 1) <= v {
        x += 1;
    }
    while x * x > v {
        x -= 1;
    }
    x
}

/// Sobel gradient magnitude through a multiplier family (16-bit signed for
/// both the kernel taps and the squaring).
pub fn edge_detect(img: &Image, family: &MultFamily) -> Image {
    let f = behavioral_fn(family, 16);
    let mul = |a: i64, b: i64| -> i64 { signed_multiply(&*f, a, b) };
    let mut out = Image::new(img.w, img.h);
    for y in 1..img.h - 1 {
        for x in 1..img.w - 1 {
            let mut gx = 0i64;
            let mut gy = 0i64;
            for ky in 0..3 {
                for kx in 0..3 {
                    let p = img.get(x + kx - 1, y + ky - 1) as i64;
                    if SOBEL_X[ky][kx] != 0 {
                        gx += mul(p, SOBEL_X[ky][kx]);
                    }
                    if SOBEL_Y[ky][kx] != 0 {
                        gy += mul(p, SOBEL_Y[ky][kx]);
                    }
                }
            }
            // Squares via the same signed multiplier; |g| <= 1020 fits 16-bit.
            let g2 = mul(gx, gx) + mul(gy, gy);
            let mag = isqrt(g2.max(0) as u64);
            out.set(x, y, mag.min(255) as u8);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::images;

    #[test]
    fn isqrt_exact() {
        for v in 0..2000u64 {
            let r = isqrt(v);
            assert!(r * r <= v && (r + 1) * (r + 1) > v, "isqrt({v}) = {r}");
        }
        assert_eq!(isqrt(u32::MAX as u64), 65535);
    }

    #[test]
    fn exact_edge_matches_reference_sobel() {
        let img = images::cameraman(48);
        let ours = edge_detect(&img, &MultFamily::Exact);
        // independent reference
        for y in 1..img.h - 1 {
            for x in 1..img.w - 1 {
                let mut gx = 0i64;
                let mut gy = 0i64;
                for ky in 0..3 {
                    for kx in 0..3 {
                        let p = img.get(x + kx - 1, y + ky - 1) as i64;
                        gx += p * SOBEL_X[ky][kx];
                        gy += p * SOBEL_Y[ky][kx];
                    }
                }
                let mag = isqrt((gx * gx + gy * gy) as u64).min(255) as u8;
                assert_eq!(ours.get(x, y), mag, "({x},{y})");
            }
        }
    }

    #[test]
    fn flat_image_has_no_edges() {
        let mut img = Image::new(16, 16);
        img.px.fill(100);
        let e = edge_detect(&img, &MultFamily::Exact);
        assert!(e.px.iter().all(|&p| p == 0));
    }

    #[test]
    fn edges_respond_to_boundaries() {
        let img = images::cameraman(64);
        let e = edge_detect(&img, &MultFamily::Exact);
        let max = *e.px.iter().max().unwrap();
        assert!(max > 100, "strong silhouette edge expected, max {max}");
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn approximate_edges_preserve_structure() {
        let img = images::boat(64);
        let exact = edge_detect(&img, &MultFamily::Exact);
        let appro = edge_detect(&img, &MultFamily::default_approx(16));
        // Count strong-edge pixels: sets should mostly agree.
        let strong = |im: &Image| -> Vec<bool> { im.px.iter().map(|&p| p > 60).collect() };
        let (se, sa) = (strong(&exact), strong(&appro));
        let agree = se.iter().zip(&sa).filter(|(a, b)| a == b).count();
        let frac = agree as f64 / se.len() as f64;
        assert!(frac > 0.97, "edge maps agree only {frac:.3}");
    }
}
