//! PSNR (peak signal-to-noise ratio) against the exact-multiplier baseline
//! — Table III's quality metric. Above 40 dB ≈ visually identical; below
//! 30 dB ≈ visible degradation (paper §V-B).

use super::images::Image;

/// PSNR in dB between a reference and a test image. Identical images
/// return +inf.
pub fn psnr_db(reference: &Image, test: &Image) -> f64 {
    assert_eq!((reference.w, reference.h), (test.w, test.h));
    let mse: f64 = reference
        .px
        .iter()
        .zip(&test.px)
        .map(|(&a, &b)| {
            let d = a as f64 - b as f64;
            d * d
        })
        .sum::<f64>()
        / reference.px.len() as f64;
    if mse == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (255.0f64 * 255.0 / mse).log10()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::images;

    #[test]
    fn identical_images_are_infinite() {
        let a = images::lake(32);
        assert!(psnr_db(&a, &a).is_infinite());
    }

    #[test]
    fn single_level_error_is_about_48db() {
        let a = images::lake(64);
        let mut b = a.clone();
        for p in b.px.iter_mut() {
            *p = p.saturating_add(1);
        }
        let v = psnr_db(&a, &b);
        assert!((v - 48.13).abs() < 0.2, "psnr {v}");
    }

    #[test]
    fn more_noise_is_lower_psnr() {
        let a = images::boat(64);
        let mut b1 = a.clone();
        let mut b4 = a.clone();
        for (i, p) in b1.px.iter_mut().enumerate() {
            if i % 2 == 0 {
                *p = p.saturating_add(2);
            }
        }
        for (i, p) in b4.px.iter_mut().enumerate() {
            if i % 2 == 0 {
                *p = p.saturating_add(8);
            }
        }
        assert!(psnr_db(&a, &b1) > psnr_db(&a, &b4));
    }
}
