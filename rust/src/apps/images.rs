//! Procedural grayscale test images, stand-ins for the classic USC-SIPI
//! set. Each generator is deterministic and mimics the texture character
//! of its namesake (smooth water + gradients vs. high-frequency fur vs.
//! geometric edges), which is what differentiates PSNR rows in Table III.

use crate::util::rng::Pcg32;

/// A grayscale image, row-major u8.
#[derive(Clone, Debug, PartialEq)]
pub struct Image {
    pub w: usize,
    pub h: usize,
    pub px: Vec<u8>,
}

impl Image {
    pub fn new(w: usize, h: usize) -> Self {
        Self {
            w,
            h,
            px: vec![0; w * h],
        }
    }

    #[inline]
    pub fn get(&self, x: usize, y: usize) -> u8 {
        self.px[y * self.w + x]
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, v: u8) {
        self.px[y * self.w + x] = v;
    }

    /// Mean pixel value.
    pub fn mean(&self) -> f64 {
        self.px.iter().map(|&p| p as f64).sum::<f64>() / self.px.len() as f64
    }

    /// Mean absolute horizontal gradient (texture level).
    pub fn gradient_energy(&self) -> f64 {
        let mut acc = 0f64;
        let mut n = 0f64;
        for y in 0..self.h {
            for x in 1..self.w {
                acc += (self.get(x, y) as f64 - self.get(x - 1, y) as f64).abs();
                n += 1.0;
            }
        }
        acc / n
    }
}

fn smoothstep(x: f64) -> f64 {
    let x = x.clamp(0.0, 1.0);
    x * x * (3.0 - 2.0 * x)
}

/// "lake": smooth vertical gradient + low-frequency ripples + soft shore.
pub fn lake(n: usize) -> Image {
    let mut img = Image::new(n, n);
    for y in 0..n {
        for x in 0..n {
            let fx = x as f64 / n as f64;
            let fy = y as f64 / n as f64;
            let sky = 190.0 - 90.0 * fy;
            let ripple = 18.0 * ((fx * 21.0 + fy * 4.0).sin() * (fy * 13.0).cos());
            let shore = 35.0 * smoothstep((fy - 0.72) * 8.0);
            let v = sky + ripple * smoothstep((fy - 0.45) * 6.0) - shore;
            img.set(x, y, v.clamp(0.0, 255.0) as u8);
        }
    }
    img
}

/// "mandril": high-frequency fur-like multi-octave noise.
pub fn mandril(n: usize) -> Image {
    let mut img = Image::new(n, n);
    let mut rng = Pcg32::new(0x4D414E44);
    // Value-noise lattice octaves.
    let octaves: Vec<(usize, f64, Vec<f64>)> = [(8usize, 70.0), (16, 45.0), (64, 40.0)]
        .iter()
        .map(|&(g, amp)| {
            let lattice: Vec<f64> = (0..(g + 1) * (g + 1)).map(|_| rng.next_f64()).collect();
            (g, amp, lattice)
        })
        .collect();
    for y in 0..n {
        for x in 0..n {
            let mut v = 128.0;
            for (g, amp, lat) in &octaves {
                let fx = x as f64 / n as f64 * *g as f64;
                let fy = y as f64 / n as f64 * *g as f64;
                let (ix, iy) = (fx as usize, fy as usize);
                let (tx, ty) = (fx - ix as f64, fy - iy as f64);
                let at = |i: usize, j: usize| lat[j.min(*g) * (*g + 1) + i.min(*g)];
                let top = at(ix, iy) * (1.0 - tx) + at(ix + 1, iy) * tx;
                let bot = at(ix, iy + 1) * (1.0 - tx) + at(ix + 1, iy + 1) * tx;
                v += amp * ((top * (1.0 - ty) + bot * ty) - 0.5) * 2.0;
            }
            img.set(x, y, v.clamp(0.0, 255.0) as u8);
        }
    }
    img
}

/// "jetplane": bright body with hard geometric edges on sky.
pub fn jetplane(n: usize) -> Image {
    let mut img = Image::new(n, n);
    for y in 0..n {
        for x in 0..n {
            let fx = x as f64 / n as f64;
            let fy = y as f64 / n as f64;
            let sky = 170.0 + 40.0 * fy;
            // fuselage: rotated ellipse
            let (cx, cy) = (fx - 0.5, fy - 0.45);
            let (u, v2) = (cx * 0.9 + cy * 0.45, -cx * 0.45 + cy * 0.9);
            let body = (u * u / 0.09 + v2 * v2 / 0.004) < 1.0;
            // wing: triangle-ish band
            let wing = (fy - 0.45 + 0.8 * (fx - 0.5)).abs() < 0.03 && fx > 0.25 && fx < 0.75;
            let tail = (fx - 0.72).abs() < 0.02 && fy > 0.28 && fy < 0.48;
            // dark nose marking + canopy give the image its dark tones
            let nose = ((fx - 0.3).powi(2) + (fy - 0.46).powi(2)).sqrt() < 0.035;
            let canopy = ((fx - 0.42).powi(2) + (fy - 0.42).powi(2)).sqrt() < 0.025;
            let val = if nose || canopy {
                25.0
            } else if body || wing || tail {
                235.0
            } else {
                sky
            };
            img.set(x, y, val.clamp(0.0, 255.0) as u8);
        }
    }
    img
}

/// "boat": structured masts/hull over graded water.
pub fn boat(n: usize) -> Image {
    let mut img = Image::new(n, n);
    for y in 0..n {
        for x in 0..n {
            let fx = x as f64 / n as f64;
            let fy = y as f64 / n as f64;
            let sky = 200.0 - 60.0 * fy;
            let water = fy > 0.7;
            let wave = 12.0 * ((fx * 40.0).sin() * (fy * 25.0).cos());
            let mast1 = (fx - 0.4).abs() < 0.008 && fy > 0.15 && fy < 0.7;
            let mast2 = (fx - 0.55).abs() < 0.006 && fy > 0.25 && fy < 0.7;
            let hull = fy > 0.62 && fy < 0.72 && fx > 0.28 && fx < 0.68;
            let sail = fx > 0.405 && fx < 0.54 && fy > 0.2 && fy < 0.55
                && (fx - 0.405) < (0.55 - fy) * 0.4;
            let v = if mast1 || mast2 {
                40.0
            } else if hull {
                60.0
            } else if sail {
                225.0
            } else if water {
                90.0 + wave
            } else {
                sky
            };
            img.set(x, y, v.clamp(0.0, 255.0) as u8);
        }
    }
    img
}

/// "cameraman": dark silhouette on bright background, sharp boundary.
pub fn cameraman(n: usize) -> Image {
    let mut img = Image::new(n, n);
    for y in 0..n {
        for x in 0..n {
            let fx = x as f64 / n as f64;
            let fy = y as f64 / n as f64;
            let bg = 185.0 - 25.0 * fy;
            // head
            let head = ((fx - 0.45).powi(2) + (fy - 0.3).powi(2)).sqrt() < 0.09;
            // torso
            let torso = fx > 0.34 && fx < 0.58 && fy > 0.38 && fy < 0.8
                && (fx - 0.46).abs() < 0.13 - 0.05 * (fy - 0.38);
            // tripod legs
            let leg1 = ((fx - 0.62) - 0.25 * (fy - 0.55)).abs() < 0.008 && fy > 0.55;
            let leg2 = ((fx - 0.68) + 0.18 * (fy - 0.55)).abs() < 0.008 && fy > 0.55;
            let camera = fx > 0.56 && fx < 0.68 && fy > 0.42 && fy < 0.52;
            let v = if head || torso || camera || leg1 || leg2 {
                35.0
            } else {
                bg
            };
            img.set(x, y, v.clamp(0.0, 255.0) as u8);
        }
    }
    img
}

/// Named generator lookup (paper image names, lowercase).
pub fn by_name(name: &str, n: usize) -> Option<Image> {
    Some(match name {
        "lake" => lake(n),
        "mandril" => mandril(n),
        "jetplane" => jetplane(n),
        "boat" => boat(n),
        "cameraman" => cameraman(n),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_are_deterministic() {
        assert_eq!(lake(64), lake(64));
        assert_eq!(mandril(64), mandril(64));
    }

    #[test]
    fn texture_characters_differ() {
        // mandril must be much busier than lake (fur vs water).
        let g_lake = lake(128).gradient_energy();
        let g_mandril = mandril(128).gradient_energy();
        assert!(
            g_mandril > 3.0 * g_lake,
            "mandril {g_mandril:.1} vs lake {g_lake:.1}"
        );
    }

    #[test]
    fn images_use_full_dynamic_range_sanely() {
        for name in ["lake", "mandril", "jetplane", "boat", "cameraman"] {
            let img = by_name(name, 128).unwrap();
            let mean = img.mean();
            assert!(
                (40.0..220.0).contains(&mean),
                "{name} mean {mean}"
            );
            let min = *img.px.iter().min().unwrap();
            let max = *img.px.iter().max().unwrap();
            assert!(max - min > 80, "{name} has low contrast {min}-{max}");
        }
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(by_name("lenna", 32).is_none());
    }
}
