//! Application-level evaluations (paper §V-B, Table III): image blending
//! with 8-bit unsigned approximate multipliers and Sobel edge detection
//! with 16-bit signed approximate multipliers, measured in PSNR against
//! the exact-multiplier baseline.
//!
//! The paper's standard test images (Lake, Mandril, Jetplane, Boat,
//! Cameraman) are not redistributable here; [`images`] provides named
//! procedural generators with matching texture character (DESIGN.md §3).

pub mod images;
pub mod blend;
pub mod edge;
pub mod psnr;
pub mod cli;

pub use psnr::psnr_db;
