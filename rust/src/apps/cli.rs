//! `openacm psnr` — reproduce Table III: PSNR of Appro4-2 / Log-our / LM
//! against the exact baseline on image blending and edge detection.

use anyhow::Result;

use super::images::{self, Image};
use super::{blend, edge, psnr::psnr_db};
use crate::bench::harness::Table;
use crate::config::spec::MultFamily;
use crate::util::cli::Args;

/// The three approximate families of Table III (columns), at native widths.
fn table3_families(bits: usize) -> Vec<(&'static str, MultFamily)> {
    vec![
        ("Appro4-2", MultFamily::default_approx(bits)),
        ("Log-our", MultFamily::LogOur),
        ("LM [24]", MultFamily::Mitchell),
    ]
}

/// One Table III row.
#[derive(Clone, Debug)]
pub struct PsnrRow {
    pub task: &'static str,
    pub image: String,
    /// (family label, PSNR dB) triples.
    pub psnr: Vec<(String, f64)>,
}

/// Blending rows: the paper's three image pairs.
pub fn blending_rows(n: usize) -> Vec<PsnrRow> {
    let pairs = [
        ("Lake & Mandril", "lake", "mandril"),
        ("Jetplane & Boat", "jetplane", "boat"),
        ("Cameraman & Lake", "cameraman", "lake"),
    ];
    pairs
        .iter()
        .map(|&(label, a, b)| {
            let ia = images::by_name(a, n).unwrap();
            let ib = images::by_name(b, n).unwrap();
            let exact = blend::blend(&ia, &ib, &MultFamily::Exact);
            let psnr = table3_families(8)
                .into_iter()
                .map(|(fl, fam)| {
                    let out = blend::blend(&ia, &ib, &fam);
                    (fl.to_string(), psnr_db(&exact, &out))
                })
                .collect();
            PsnrRow {
                task: "Image Blending",
                image: label.to_string(),
                psnr,
            }
        })
        .collect()
}

/// Edge-detection rows: the paper's three images.
pub fn edge_rows(n: usize) -> Vec<PsnrRow> {
    ["boat", "cameraman", "jetplane"]
        .iter()
        .map(|&name| {
            let img: Image = images::by_name(name, n).unwrap();
            let exact = edge::edge_detect(&img, &MultFamily::Exact);
            let psnr = table3_families(16)
                .into_iter()
                .map(|(fl, fam)| {
                    let out = edge::edge_detect(&img, &fam);
                    (fl.to_string(), psnr_db(&exact, &out))
                })
                .collect();
            PsnrRow {
                task: "Edge Detection",
                image: {
                    let mut s = name.to_string();
                    s.get_mut(0..1).map(|c| c.make_ascii_uppercase());
                    s
                },
                psnr,
            }
        })
        .collect()
}

/// Render the combined Table III.
pub fn render_table3(rows: &[PsnrRow]) -> Table {
    let mut t = Table::new(
        "Table III: PSNR vs exact baseline (dB)",
        &["Task", "Test Image", "Appro4-2", "Log-our", "LM [24]"],
    );
    for r in rows {
        let get = |label: &str| -> String {
            r.psnr
                .iter()
                .find(|(l, _)| l == label)
                .map(|(_, v)| format!("{v:.2}"))
                .unwrap_or_default()
        };
        t.row(&[
            r.task.to_string(),
            r.image.clone(),
            get("Appro4-2"),
            get("Log-our"),
            get("LM [24]"),
        ]);
    }
    t
}

pub fn cmd_psnr(args: &Args) -> Result<()> {
    let n = args.usize_or("size", 256)?;
    let mut rows = blending_rows(n);
    rows.extend(edge_rows(n));
    render_table3(&rows).print();
    println!(
        "\npaper reference: blending Appro4-2 67-71 dB, Log-our 32-43 dB, LM 22-26 dB;\n\
         edge detection Appro4-2 ~66-68 dB, Log-our ~44-46 dB, LM ~38-39 dB"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn table3_orderings_hold() {
        // The key qualitative claims, on smaller images for speed:
        // Appro4-2 > Log-our > LM everywhere; LM < 30 dB threshold in
        // blending while Log-our stays above it.
        for r in blending_rows(96) {
            let g = |l: &str| r.psnr.iter().find(|(x, _)| x == l).unwrap().1;
            let (ap, lo, lm) = (g("Appro4-2"), g("Log-our"), g("LM [24]"));
            assert!(ap > lo && lo > lm, "{}: {ap:.1} {lo:.1} {lm:.1}", r.image);
            assert!(lo > 30.0, "{}: log-our {lo:.1} below 30 dB", r.image);
            // Our yang1 reconstruction carries a little more MED than the
            // published cell, so the Appro4-2 PSNR lands ~50 dB instead of
            // the paper's 67–71 dB; still comfortably "near-identical"
            // (> 40 dB) and the ordering holds. See EXPERIMENTS.md.
            assert!(ap > 45.0, "{}: appro4-2 {ap:.1} too low", r.image);
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn edge_detection_orderings_hold() {
        for r in edge_rows(96) {
            let g = |l: &str| r.psnr.iter().find(|(x, _)| x == l).unwrap().1;
            let (ap, lo, lm) = (g("Appro4-2"), g("Log-our"), g("LM [24]"));
            // LM is clearly worst (paper: ~38 dB vs 44-46/66-68). Appro4-2
            // and Log-our both exceed the 40 dB "visually identical" bar;
            // their relative order flips vs the paper here because edge
            // detection squares its operands and Log-our's dynamic
            // compensation is near-exact for equal operands (Q1 == Q2) —
            // a systematic artifact documented in EXPERIMENTS.md.
            assert!(ap > lm && lo > lm, "{}: {ap:.1} {lo:.1} {lm:.1}", r.image);
            assert!(ap > 40.0 && lo > 40.0, "{}: {ap:.1}/{lo:.1}", r.image);
            assert!((ap - lo).abs() < 15.0, "{}: {ap:.1} vs {lo:.1}", r.image);
        }
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn render_has_all_rows() {
        let mut rows = blending_rows(48);
        rows.extend(edge_rows(48));
        let s = render_table3(&rows).render();
        assert!(s.contains("Lake & Mandril"));
        assert!(s.contains("Edge Detection"));
        assert!(s.contains("Cameraman"));
    }
}
