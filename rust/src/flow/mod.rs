//! Flow-script generator (paper §III-A component 4, §IV): Verilog RTL for
//! the generated netlists and the PE top, FakeRAM-style LEF/LIB for the
//! SRAM macro, SDC constraints, and the OpenROAD TCL script set
//! (synthesis → floorplan → place → CTS → route → report) so the artifact
//! bundle matches what the paper's flow consumes/produces.

pub mod verilog;
pub mod scripts;
pub mod emit;
pub mod cli;

pub use emit::{generate_all, FlowArtifacts};
