//! `openacm generate` — run the compiler end to end for one macro spec.

use anyhow::Result;
use std::path::Path;

use super::emit::generate_all;
use crate::config::spec::MacroSpec;
use crate::config::toml::TomlDoc;
use crate::ppa::cli::parse_family;
use crate::util::cli::Args;

pub fn cmd_generate(args: &Args) -> Result<()> {
    let spec: MacroSpec = match args.get("spec") {
        Some(path) => TomlDoc::load(Path::new(path))?.to_macro_spec()?,
        None => {
            let rows = args.usize_or("rows", 16)?;
            let bits = args.usize_or("word-bits", 8)?;
            let fam = parse_family(
                args.str_or("mult", "appro42"),
                bits,
                args.str_or("compressor", "yang1"),
                args.usize_or("approx-cols", bits)?,
            )?;
            MacroSpec::new(&format!("dcim{rows}x{bits}"), rows, bits, fam)
        }
    };
    let out = args.str_or("out", "build/flow");
    let art = generate_all(&spec, Path::new(out))?;
    println!(
        "generated {} artifacts in {}:",
        art.files.len(),
        art.dir.display()
    );
    for f in &art.files {
        println!("  {}", f.file_name().unwrap().to_string_lossy());
    }
    println!("\n{}", art.ppa_summary);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn generate_via_cli_args() {
        let tmp = std::env::temp_dir().join(format!("openacm_gencli_{}", std::process::id()));
        let args = Args::parse(
            vec![
                "generate".to_string(),
                "--rows".into(),
                "16".into(),
                "--word-bits".into(),
                "8".into(),
                "--mult".into(),
                "logour".into(),
                format!("--out={}", tmp.display()),
            ],
            true,
            &[],
        )
        .unwrap();
        cmd_generate(&args).unwrap();
        assert!(tmp.join("mult_logour_8b.v").exists());
        std::fs::remove_dir_all(&tmp).ok();
    }
}
