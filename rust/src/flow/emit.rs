//! Top-level artifact emission: run the whole compiler for one macro spec
//! and write the full artifact bundle to a directory.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

use super::{scripts, verilog};
use crate::config::spec::MacroSpec;
use crate::ppa::report::analyze_macro;
use crate::sram::fakeram;

/// The artifact bundle produced for one macro.
#[derive(Clone, Debug)]
pub struct FlowArtifacts {
    pub dir: PathBuf,
    pub files: Vec<PathBuf>,
    /// Quick PPA summary computed alongside generation.
    pub ppa_summary: String,
}

/// Generate everything for one spec into `out_dir`:
/// Verilog (multiplier netlist + PE top + SRAM behavioral), LEF, LIB,
/// SDC, OpenROAD TCL set, flow Makefile, and a PPA report.
pub fn generate_all(spec: &MacroSpec, out_dir: &Path) -> Result<FlowArtifacts> {
    spec.validate()?;
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    std::fs::create_dir_all(out_dir.join("results")).ok();
    std::fs::create_dir_all(out_dir.join("logs")).ok();
    let mut files = Vec::new();
    let mut emit = |name: String, content: String| -> Result<()> {
        let p = out_dir.join(&name);
        std::fs::write(&p, content).with_context(|| format!("writing {}", p.display()))?;
        files.push(p);
        Ok(())
    };

    // RTL
    let mult_nl = crate::mult::build_netlist(&spec.mult);
    let mult_module = mult_nl.name.clone();
    emit(
        format!("{mult_module}.v"),
        verilog::netlist_to_verilog(&mult_nl),
    )?;
    emit(
        format!("{}_pe_top.v", spec.name),
        verilog::pe_top_verilog(spec, &mult_module),
    )?;
    let sram_name = fakeram::macro_name(&spec.sram);
    emit(format!("{sram_name}.v"), fakeram::verilog(&spec.sram))?;
    // Abstract views
    emit(format!("{sram_name}.lef"), fakeram::lef(&spec.sram))?;
    emit(
        format!("{sram_name}.lib"),
        fakeram::lib(&spec.sram, spec.clock_mhz),
    )?;
    // Constraints + flow scripts
    emit(format!("{}.sdc", spec.name), scripts::sdc(spec))?;
    emit("synth.tcl".into(), scripts::synth_tcl(spec, &mult_module))?;
    emit("floorplan.tcl".into(), scripts::floorplan_tcl(spec))?;
    emit("place.tcl".into(), scripts::place_tcl(spec))?;
    emit("cts.tcl".into(), scripts::cts_tcl(spec))?;
    emit("route.tcl".into(), scripts::route_tcl(spec))?;
    emit("Makefile".into(), scripts::flow_makefile(spec))?;

    // PPA summary (our signoff substitute).
    let ppa = analyze_macro(spec, 2000, 0x7AB1E2);
    let summary = format!(
        "macro {}\n  family       {}\n  delay        {:.2} ns\n  logic area   {:.0} um2\n  sram area    {:.0} um2\n  p&r area     {:.0} um2\n  power        {:.3e} W\n  energy/op    {:.3e} J\n  mult gates   {}\n",
        ppa.name,
        ppa.family_label,
        ppa.delay_ns,
        ppa.logic_area_um2,
        ppa.sram_area_um2,
        ppa.pnr_area_um2,
        ppa.power_w,
        ppa.energy_per_op_j,
        ppa.mult_gates
    );
    emit("ppa_report.txt".into(), summary.clone())?;

    Ok(FlowArtifacts {
        dir: out_dir.to_path_buf(),
        files,
        ppa_summary: summary,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::{MacroSpec, MultFamily};

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn generates_complete_bundle() {
        let tmp = std::env::temp_dir().join(format!("openacm_flow_{}", std::process::id()));
        let spec = MacroSpec::new("dcim16x8", 16, 8, MultFamily::default_approx(8));
        let art = generate_all(&spec, &tmp).unwrap();
        let names: Vec<String> = art
            .files
            .iter()
            .map(|p| p.file_name().unwrap().to_string_lossy().to_string())
            .collect();
        for expect in [
            "dcim16x8_pe_top.v",
            "fakeram45_16x8.v",
            "fakeram45_16x8.lef",
            "fakeram45_16x8.lib",
            "dcim16x8.sdc",
            "synth.tcl",
            "floorplan.tcl",
            "place.tcl",
            "cts.tcl",
            "route.tcl",
            "Makefile",
            "ppa_report.txt",
        ] {
            assert!(names.iter().any(|n| n == expect), "missing {expect}: {names:?}");
        }
        assert!(art.ppa_summary.contains("Appro4-2"));
        std::fs::remove_dir_all(&tmp).ok();
    }
}
