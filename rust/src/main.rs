//! OpenACM command-line interface (leader entrypoint).
//!
//! Subcommands map one-to-one onto the paper's experiments and the compiler
//! stages; `openacm help` prints the catalogue. The real implementations
//! live in the library; this file only does argument plumbing.

use anyhow::Result;
use openacm::util::cli::Args;

const USAGE: &str = r#"OpenACM — open-source SRAM-based approximate CiM compiler (reproduction)

USAGE: openacm <command> [options]

COMMANDS:
  generate   Compile a DCiM macro: netlists, Verilog, LEF/LIB, OpenROAD scripts
             --rows N --word-bits N [--mult exact|appro42|logour|mitchell|adder_tree]
             [--compressor yang1|...] [--approx-cols N] [--out DIR] [--spec FILE]
  ppa        Reproduce Table II rows for one configuration
             --rows N --word-bits N [--mult ...]
  psnr       Reproduce Table III (image blending + edge detection PSNR)
  nn         Reproduce Table IV (Top-1/Top-5 + NMED/MRED) via the PJRT runtime
             [--artifacts DIR]
  yield      Reproduce Table V (MC vs MNIS) [--size 16|32|64] [--seed N]
  dse        Accuracy-energy design-space exploration (Pareto frontier)
             [--no-cache] [--store DIR]
  compile    Accuracy-budgeted per-layer multiplier mapping: emit a
             compiled heterogeneous plan (.acmplan) the serving stack
             executes directly
             --budget PCT [--spec FILE] [--calib N] [--seed N]
             [--out FILE] [--artifacts DIR] [--store DIR] [--no-cache]
             [--smoke] [--no-incremental]
  store      Inspect/maintain the design-point store: stats | verify | gc
             [--dir DIR] [--repair] [--max-mb N] [--json]
  serve      Start the sharded, SLO-aware inference coordinator (PJRT on
             AOT artifacts, or the artifact-free batched native backend)
             [--backend native|pjrt|auto] [--artifacts DIR] [--batch N]
             [--requests N] [--store DIR] [--seed N]
             [--shards N]  coordinator shards behind consistent-hash
             routing  [--slo-ms N]  latency SLO the deadline-bucket
             batcher closes against
             [--classes gold,silver,...]  route half the stream by
             accuracy class (exact|gold|silver|bronze|best-effort|0.5%)
             [--metrics-every N]  emit + flush a telemetry snapshot every
             N requests  [--obs-dir DIR]
             [--plan FILE.acmplan]  serve a compiled heterogeneous plan as
             the "plan" variant (native per-layer LUT dispatch)
             [--threads N]  execution-pool thread budget
             resilience (all off by default):
             [--retries N]  retry transient execute failures with backoff
             [--hedge MS]  hedge requests with ≥ MS deadline slack onto a
             second shard (first success wins)  [--breaker]  per-variant
             circuit breakers + degradation ladder  [--respawn N]
             panicked-executor restart budget  [--autoscale N]  grow each
             executor pool to ≤ N workers under queue-wait pressure
             [--chaos SEED]  serve the fixture menu under a seeded fault
             plan (chaos smoke for the above)
  obs        Inspect the telemetry sink:
             snapshot | tail | diff | trace | health | regress
             [--dir DIR] [--n K] [--json]  (see also OPENACM_TRACE)
             tail --follow [--interval-ms MS] [--max-polls K]  follow
             appends like tail -f; diff exits 1 when non-empty
             trace [--slowest N] [--failed]  per-request stage timelines
             from <dir>/trace.json (tail-sampled; Chrome trace format)
             health [--json]  SLO burn-rate states + p99 exemplar; exits
             2 while any objective burns at error rate
             regress --baseline DIR [--current DIR] [--tolerance PCT]
             [--times]  perf gate over BENCH_*.json; exits 1 on regression
  luts       Emit behavioral-multiplier LUTs (npy) for cross-checking
             [--out DIR]
  help       Show this message
"#;

fn main() -> Result<()> {
    let args = Args::from_env(
        true,
        &[
            "verbose",
            "fast",
            "no-cache",
            "repair",
            "smoke",
            "no-incremental",
            "json",
            "follow",
            "failed",
            "times",
            "breaker",
        ],
    )?;
    match args.command.as_deref() {
        Some("generate") => openacm::flow::cli::cmd_generate(&args),
        Some("ppa") => openacm::ppa::cli::cmd_ppa(&args),
        Some("psnr") => openacm::apps::cli::cmd_psnr(&args),
        Some("nn") => openacm::nn::cli::cmd_nn(&args),
        Some("yield") => openacm::yield_analysis::cli::cmd_yield(&args),
        Some("dse") => openacm::dse::cli::cmd_dse(&args),
        Some("compile") => openacm::compile::cli::cmd_compile(&args),
        Some("store") => openacm::store::cli::cmd_store(&args),
        Some("serve") => openacm::coordinator::cli::cmd_serve(&args),
        Some("obs") => openacm::obs::cli::cmd_obs(&args),
        Some("luts") => openacm::mult::cli::cmd_luts(&args),
        Some("help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown command {other:?}\n");
            print!("{USAGE}");
            std::process::exit(2);
        }
    }
}
