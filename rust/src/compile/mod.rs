//! The accuracy-budgeted compiler pass (the paper's §III-A promise made
//! executable): given a quantized model and a top-1 accuracy budget,
//! search **per-layer** heterogeneous multiplier assignments over the
//! full `mult::` family space and emit a versioned, checksummed
//! [`CompiledPlan`] artifact that the serving stack loads and executes
//! directly.
//!
//! * [`search`] — the optimizer: per-layer sensitivity profiling, greedy
//!   energy descent with true-accuracy validation, pairwise-swap local
//!   refinement; every measurement memoized in the design-point store
//!   (`model hash × assignment × calibration hash`), so repeated compiles
//!   and budget sweeps are store-warm. Fresh measurements run through the
//!   **incremental evaluator**: prefix-activation checkpoints (pinned
//!   all-exact chain + LRU) and sparse linear delta replay make each
//!   accuracy probe cost only the suffix from the first changed layer,
//!   bit-identically to the full forward (see DESIGN.md §Compile pass
//!   "Incremental evaluation"; `--no-incremental` keeps the full path
//!   for A/B debugging).
//! * [`plan`] — the `.acmplan` artifact: per-layer multiplier config +
//!   energy/MAC bookkeeping + baseline/plan accuracy, with magic/version/
//!   checksum framing; [`CompiledPlan::build_luts`] reconstructs the
//!   bit-identical per-layer LUTs on load.
//! * [`cli`] — `openacm compile`.
//!
//! Execution: [`crate::nn::model::QuantCnn::forward_batch_hetero`]
//! dispatches each layer through its own LUT, and
//! [`crate::runtime::NativeFactory::add_plan`] registers a plan as a
//! serving variant, so a compiled heterogeneous design round-trips
//! through the coordinator with logits bit-matching a direct forward.

pub mod cli;
pub mod plan;
pub mod search;

pub use plan::{CompiledPlan, LayerPlan, PlanLuts, PLAN_VERSION};
pub use search::{
    compile_budgeted, candidate_space, model_content_hash, CalibrationSet, Candidate,
    CompileOptions, Compiler, SearchStats,
};
