//! `openacm compile` — the accuracy-budgeted compiler pass.
//!
//! ```text
//! openacm compile --spec specs/dcim16x8_appro42.toml --budget 0.5
//!     [--calib N] [--seed N] [--threads N] [--out plan.acmplan]
//!     [--artifacts DIR] [--store DIR | --no-cache] [--smoke]
//!     [--no-incremental]
//! ```
//!
//! `--budget` is the allowed top-1 drop vs the all-exact baseline in
//! percentage points (0.5 = 0.5%). The spec supplies the macro geometry
//! behind the energy model; the quantized model comes from the AOT
//! artifact bundle when present, else a deterministic synthetic model.
//! `--smoke` runs the CI configuration: tiny calibration set, reduced
//! candidate space, only the two fc layers searchable.
//! `--no-incremental` disables suffix-replay evaluation (A/B debugging
//! escape hatch: the emitted plan is byte-identical either way, only the
//! amount of replayed GEMM work differs — see DESIGN.md §Compile pass).

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::time::Instant;

use super::plan::CompiledPlan;
use super::search::{CalibrationSet, CompileOptions, Compiler};
use crate::bench::harness::{sci, Table};
use crate::config::toml::TomlDoc;
use crate::nn::model::{QuantCnn, IMG};
use crate::runtime::ArtifactStore;
use crate::util::cli::Args;
use crate::util::threadpool::ThreadPool;

pub fn cmd_compile(args: &Args) -> Result<()> {
    let budget_pct = args.f64_or("budget", 0.5)?;
    if !(0.0..=100.0).contains(&budget_pct) {
        bail!("--budget is a top-1 drop in percentage points (0..=100), got {budget_pct}");
    }
    // Telemetry: stream events to the default sink dir and flush a merged
    // metrics snapshot at the end (shared with `openacm serve`).
    if let Err(e) = crate::obs::init(&crate::obs::default_dir()) {
        eprintln!("telemetry sink unavailable ({e:#}); events stay in-process");
    }
    let smoke = args.flag("smoke");
    let mut opts = if smoke {
        CompileOptions::smoke(budget_pct / 100.0)
    } else {
        CompileOptions::new(budget_pct / 100.0)
    };

    let (spec_name, rows) = match args.get("spec") {
        Some(path) => {
            let spec = TomlDoc::load(Path::new(path))?
                .to_macro_spec()
                .with_context(|| format!("loading spec {path}"))?;
            if spec.mult.bits != 8 {
                bail!(
                    "compile targets the int8 LUT datapath; spec {} is {}-bit \
                     (use an 8-bit spec such as specs/dcim16x8_appro42.toml)",
                    spec.name,
                    spec.mult.bits
                );
            }
            (spec.name, spec.sram.rows)
        }
        None => ("synthetic".to_string(), 16),
    };
    opts.rows = rows;
    opts.calib_n = args.usize_or("calib", opts.calib_n)?;
    opts.seed = args.u64_or("seed", opts.seed)?;
    opts.threads = args.usize_or("threads", ThreadPool::default_parallelism())?;
    opts.incremental = !args.flag("no-incremental");
    let store = crate::store::cli::store_from_args(args)?;

    // Real quantized weights AND the real labeled dataset when the AOT
    // artifact bundle is on disk — the budget guarantee must be measured
    // on the distribution the plan will serve, not on noise. Without
    // artifacts: the deterministic synthetic model + exact-labeled
    // synthetic images (same fallback as serving).
    let artifacts = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(ArtifactStore::default_dir);
    let (model, calib) = if ArtifactStore::exists(&artifacts) {
        println!(
            "model: quantized weights + calibration dataset from {}",
            artifacts.display()
        );
        let bundle = ArtifactStore::load(&artifacts)?;
        let model = QuantCnn::load(&artifacts)?;
        let n = opts.calib_n.min(bundle.n_images);
        let calib = CalibrationSet::from_parts(
            bundle.images[..n * IMG * IMG].to_vec(),
            bundle.labels[..n].to_vec(),
        );
        (model, calib)
    } else {
        println!(
            "model: synthetic QuantCnn (seed {}) — no artifacts in {}",
            opts.seed,
            artifacts.display()
        );
        let model = QuantCnn::random(opts.seed);
        let calib = CalibrationSet::synthetic(&model, opts.calib_n, opts.seed, opts.threads);
        (model, calib)
    };

    println!(
        "compiling {spec_name}: budget {budget_pct}% top-1 drop, {} calibration images{}",
        calib.n,
        if smoke { " [smoke]" } else { "" }
    );
    let t0 = Instant::now();
    let compiler = Compiler::new(&model, &calib, opts.clone(), store.as_ref());
    let mut plan = compiler.compile();
    plan.name = format!("{spec_name}_b{budget_pct}");
    let elapsed = t0.elapsed();
    let stats = compiler.stats();

    print_plan(&plan);
    if opts.incremental {
        println!(
            "incremental evaluation: {} measurements ({} memoized, {} store-served, \
             {} free via LUT canonicalization), {:.1}x fewer GEMM MACs than cold \
             ({} replayed vs {} cold-equivalent, {} as sparse deltas)",
            stats.evaluations,
            stats.memo_hits,
            stats.store_hits,
            stats.free_probes,
            stats.mac_reduction(),
            stats.replayed_macs,
            stats.full_macs,
            stats.delta_macs,
        );
    }
    println!(
        "\ncompiled in {:.2}s: measured top-1 {:.4} (exact {:.4}, drop {:.2}% <= budget {budget_pct}%), \
         energy/image {} J vs exact {} J ({:.1}% saving)",
        elapsed.as_secs_f64(),
        plan.plan_top1,
        plan.exact_top1,
        plan.drop_vs_exact() * 100.0,
        sci(plan.plan_energy_per_image_j),
        sci(plan.exact_energy_per_image_j),
        plan.energy_saving() * 100.0
    );

    let out = PathBuf::from(args.str_or("out", "compiled_plan.acmplan"));
    plan.save(&out)?;
    println!("wrote plan {}", out.display());
    if let Some(store) = &store {
        println!("store {}: {}", store.root().display(), store.stats().summary());
    }
    // Persist the compile-side telemetry (compile.* counters, span
    // histograms) so `openacm obs snapshot` after a compile+serve session
    // shows both subsystems. A sink failure never fails the compile.
    crate::obs::info(
        "compile",
        "compile complete",
        &[
            ("plan", plan.name.clone()),
            ("evaluations", stats.evaluations.to_string()),
        ],
    );
    match crate::obs::flush(&crate::obs::default_dir()) {
        Ok(path) => println!("telemetry snapshot: {}", path.display()),
        Err(e) => eprintln!("could not flush telemetry snapshot: {e:#}"),
    }
    Ok(())
}

/// Print a plan's per-layer assignment table.
pub fn print_plan(plan: &CompiledPlan) {
    let mut t = Table::new(
        &format!("compiled plan {} (budget {:.2}%)", plan.name, plan.budget_drop * 100.0),
        &["Layer", "Multiplier", "Energy/op (J)", "MACs/image", "Solo drop"],
    );
    for l in &plan.layers {
        t.row(&[
            l.layer.clone(),
            l.family.name(),
            sci(l.energy_per_op_j),
            l.macs_per_image.to_string(),
            format!("{:.2}%", l.solo_drop * 100.0),
        ]);
    }
    t.print();
}
