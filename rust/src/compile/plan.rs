//! The compiled-plan artifact: a versioned, checksummed, per-layer
//! heterogeneous multiplier assignment that the serving stack loads and
//! executes directly.
//!
//! On-disk layout of one `.acmplan` file (all integers little-endian,
//! floats as exact bit patterns — a save/load round-trip is bit-identical):
//!
//! ```text
//! magic     8 B   "OACMPLAN"
//! version   4 B   PLAN_VERSION (LE) — mismatches are a hard load error
//! length    8 B   payload byte count
//! payload   N B   plan body (name, budget, hashes, baseline + plan
//!                 accuracy/energy, one entry per layer)
//! checksum  8 B   checksum64 over everything above
//! ```
//!
//! The plan stores each layer's multiplier *configuration*, not its LUT:
//! LUTs are pure functions of the family ([`int8_lut`]), so
//! [`CompiledPlan::build_luts`] reconstructs bit-identical tables on load
//! and the artifact stays a few hundred bytes instead of megabytes.

use anyhow::{bail, Context, Result};
use std::path::Path;
use std::sync::Arc;

use crate::config::spec::{CompressorKind, MultFamily};
use crate::mult::behavioral::int8_lut;
use crate::nn::model::{LayerLuts, LAYER_NAMES, N_LAYERS};
use crate::store::key::checksum64;
use crate::store::wire::{put_f64, put_str, put_u32, put_u64, Reader};

pub const PLAN_MAGIC: &[u8; 8] = b"OACMPLAN";
pub const PLAN_VERSION: u32 = 1;
/// Plan file extension (`<name>.acmplan`).
pub const PLAN_EXT: &str = "acmplan";

/// One layer's slot in a compiled plan.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerPlan {
    /// Layer name (matches [`LAYER_NAMES`]).
    pub layer: String,
    /// The multiplier configuration assigned to this layer.
    pub family: MultFamily,
    /// Energy per multiply for this configuration, J (PPA estimate).
    pub energy_per_op_j: f64,
    /// Multiply count of this layer per image.
    pub macs_per_image: u64,
    /// Solo sensitivity: measured top-1 drop when only this layer runs
    /// this configuration (0 for exact; informational).
    pub solo_drop: f64,
}

/// A compiled heterogeneous multiplier plan — the compile pass's output
/// and the serving stack's input.
#[derive(Clone, Debug, PartialEq)]
pub struct CompiledPlan {
    /// Human-readable plan name (spec name + budget by convention).
    pub name: String,
    /// Operand width of the LUT datapath (always 8 today).
    pub bits: u32,
    /// The accuracy budget the search ran under: allowed top-1 drop vs
    /// the all-exact baseline, as a fraction (0.005 = 0.5%).
    pub budget_drop: f64,
    /// Content hash of the quantized model the plan was compiled for.
    pub model_hash: u128,
    /// Content hash of the calibration set.
    pub calib_hash: u128,
    /// Calibration-set size.
    pub calib_n: u64,
    /// Measured top-1 of the all-exact baseline on the calibration set.
    pub exact_top1: f64,
    /// Measured top-1 of this plan on the calibration set.
    pub plan_top1: f64,
    /// Energy-per-image estimate of the all-exact baseline, J.
    pub exact_energy_per_image_j: f64,
    /// Energy-per-image estimate of this plan, J.
    pub plan_energy_per_image_j: f64,
    /// Per-layer assignments, in [`LAYER_NAMES`] order.
    pub layers: Vec<LayerPlan>,
}

impl CompiledPlan {
    /// Measured top-1 drop vs the all-exact baseline (the quantity the
    /// budget constrains).
    pub fn drop_vs_exact(&self) -> f64 {
        self.exact_top1 - self.plan_top1
    }

    /// Estimated energy saving vs all-exact, as a fraction (0.3 = 30%).
    pub fn energy_saving(&self) -> f64 {
        if self.exact_energy_per_image_j <= 0.0 {
            return 0.0;
        }
        1.0 - self.plan_energy_per_image_j / self.exact_energy_per_image_j
    }

    /// Mean energy per multiply under this plan, J (plan energy spread
    /// over the total MAC count) — the unit serving profiles report.
    pub fn energy_per_op_j(&self) -> f64 {
        let macs: u64 = self.layers.iter().map(|l| l.macs_per_image).sum();
        if macs == 0 {
            return 0.0;
        }
        self.plan_energy_per_image_j / macs as f64
    }

    /// Compact one-line assignment descriptor, e.g.
    /// `exact,appro42[kongx4],log-our,exact`.
    pub fn assignment_label(&self) -> String {
        self.layers
            .iter()
            .map(|l| l.family.name())
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Build the per-layer LUTs this plan executes through. Deterministic
    /// (LUTs are pure functions of the family), so a loaded plan serves
    /// bit-identically to the plan the compiler measured.
    pub fn build_luts(&self) -> PlanLuts {
        assert_eq!(self.layers.len(), N_LAYERS, "plan must cover every layer");
        let mut layers: Vec<Arc<Vec<i32>>> = Vec::with_capacity(N_LAYERS);
        for (i, lp) in self.layers.iter().enumerate() {
            // Reuse an identical earlier LUT (common: several layers share
            // one family) instead of recomputing the 65536-entry table.
            let lut = match self.layers[..i].iter().position(|p| p.family == lp.family) {
                Some(j) => Arc::clone(&layers[j]),
                None => Arc::new(int8_lut(&lp.family)),
            };
            layers.push(lut);
        }
        PlanLuts {
            layers: layers.try_into().expect("exactly N_LAYERS entries"),
        }
    }

    // -- serialization ------------------------------------------------------

    /// Serialize with header + checksum footer.
    pub fn encode(&self) -> Vec<u8> {
        let mut payload = Vec::with_capacity(256);
        put_str(&mut payload, &self.name);
        put_u32(&mut payload, self.bits);
        put_f64(&mut payload, self.budget_drop);
        payload.extend_from_slice(&self.model_hash.to_le_bytes());
        payload.extend_from_slice(&self.calib_hash.to_le_bytes());
        put_u64(&mut payload, self.calib_n);
        put_f64(&mut payload, self.exact_top1);
        put_f64(&mut payload, self.plan_top1);
        put_f64(&mut payload, self.exact_energy_per_image_j);
        put_f64(&mut payload, self.plan_energy_per_image_j);
        put_u32(&mut payload, self.layers.len() as u32);
        for l in &self.layers {
            put_str(&mut payload, &l.layer);
            put_family(&mut payload, &l.family);
            put_f64(&mut payload, l.energy_per_op_j);
            put_u64(&mut payload, l.macs_per_image);
            put_f64(&mut payload, l.solo_drop);
        }
        let mut out = Vec::with_capacity(28 + payload.len());
        out.extend_from_slice(PLAN_MAGIC);
        put_u32(&mut out, PLAN_VERSION);
        put_u64(&mut out, payload.len() as u64);
        out.extend_from_slice(&payload);
        let sum = checksum64(&out);
        put_u64(&mut out, sum);
        out
    }

    /// Decode and fully validate one plan image. Every failure mode —
    /// short file, bad magic, version skew, truncation, checksum mismatch,
    /// wrong layer count or order — is an `Err`: a plan either loads
    /// exactly as compiled or not at all.
    pub fn decode(bytes: &[u8]) -> Result<CompiledPlan> {
        if bytes.len() < 28 {
            bail!("plan too short: {} bytes", bytes.len());
        }
        if &bytes[..8] != PLAN_MAGIC {
            bail!("bad plan magic (not an .acmplan file)");
        }
        let body = &bytes[..bytes.len() - 8];
        let sum = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        if checksum64(body) != sum {
            bail!("plan checksum mismatch (torn or corrupted file)");
        }
        let mut r = Reader { buf: body, pos: 8 };
        let version = r.u32()?;
        if version != PLAN_VERSION {
            bail!("plan version {version} != {PLAN_VERSION}");
        }
        let payload_len = r.u64()? as usize;
        if r.buf.len() - r.pos != payload_len {
            bail!(
                "payload length {} != header claim {payload_len}",
                r.buf.len() - r.pos
            );
        }
        let name = r.str()?;
        let bits = r.u32()?;
        let budget_drop = r.f64()?;
        let model_hash = u128::from_le_bytes(r.take(16)?.try_into().unwrap());
        let calib_hash = u128::from_le_bytes(r.take(16)?.try_into().unwrap());
        let calib_n = r.u64()?;
        let exact_top1 = r.f64()?;
        let plan_top1 = r.f64()?;
        let exact_energy_per_image_j = r.f64()?;
        let plan_energy_per_image_j = r.f64()?;
        let n_layers = r.u32()? as usize;
        if n_layers != N_LAYERS {
            bail!("plan covers {n_layers} layers, this network has {N_LAYERS}");
        }
        let mut layers = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            let layer = r.str()?;
            if layer != LAYER_NAMES[i] {
                bail!(
                    "layer {i} is {layer:?}, expected {:?} (plans are ordered)",
                    LAYER_NAMES[i]
                );
            }
            let family = read_family(&mut r)?;
            let energy_per_op_j = r.f64()?;
            let macs_per_image = r.u64()?;
            let solo_drop = r.f64()?;
            layers.push(LayerPlan {
                layer,
                family,
                energy_per_op_j,
                macs_per_image,
                solo_drop,
            });
        }
        if r.pos != r.buf.len() {
            bail!("{} trailing payload bytes", r.buf.len() - r.pos);
        }
        Ok(CompiledPlan {
            name,
            bits,
            budget_drop,
            model_hash,
            calib_hash,
            calib_n,
            exact_top1,
            plan_top1,
            exact_energy_per_image_j,
            plan_energy_per_image_j,
            layers,
        })
    }

    /// Write the plan to `path` — temp file, fsync, then rename, the same
    /// durability convention as store records (a crash can never leave a
    /// torn plan at the final path with its data unflushed).
    pub fn save(&self, path: &Path) -> Result<()> {
        let bytes = self.encode();
        let tmp = path.with_extension("acmplan.tmp");
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            std::io::Write::write_all(&mut f, &bytes)
                .with_context(|| format!("writing {}", tmp.display()))?;
            f.sync_all().ok();
        }
        if let Err(e) = std::fs::rename(&tmp, path) {
            let _ = std::fs::remove_file(&tmp);
            return Err(e).with_context(|| format!("renaming into {}", path.display()));
        }
        Ok(())
    }

    /// Load and validate a plan from `path`.
    pub fn load(path: &Path) -> Result<CompiledPlan> {
        let bytes =
            std::fs::read(path).with_context(|| format!("reading plan {}", path.display()))?;
        Self::decode(&bytes).with_context(|| format!("decoding plan {}", path.display()))
    }
}

/// The materialized per-layer LUTs of a compiled plan (layers sharing a
/// family share one `Arc`'d table).
#[derive(Clone, Debug)]
pub struct PlanLuts {
    pub layers: [Arc<Vec<i32>>; N_LAYERS],
}

impl PlanLuts {
    /// One LUT on every layer (the uniform/homogeneous configuration).
    pub fn uniform(lut: Arc<Vec<i32>>) -> PlanLuts {
        PlanLuts {
            layers: [Arc::clone(&lut), Arc::clone(&lut), Arc::clone(&lut), lut],
        }
    }

    /// Borrowed view for the forward paths.
    pub fn layer_luts(&self) -> LayerLuts<'_> {
        LayerLuts {
            conv1: &self.layers[0],
            conv2: &self.layers[1],
            fc1: &self.layers[2],
            fc2: &self.layers[3],
        }
    }
}

// -- family (de)serialization -----------------------------------------------

fn put_family(out: &mut Vec<u8>, f: &MultFamily) {
    match f {
        MultFamily::Exact => out.push(0),
        MultFamily::Approx42 {
            compressor,
            approx_cols,
        } => {
            out.push(1);
            put_str(out, compressor.name());
            put_u32(out, *approx_cols as u32);
        }
        MultFamily::LogOur => out.push(2),
        MultFamily::Mitchell => out.push(3),
        MultFamily::AdderTree => out.push(4),
    }
}

fn read_family(r: &mut Reader) -> Result<MultFamily> {
    Ok(match r.u8()? {
        0 => MultFamily::Exact,
        1 => {
            let comp = CompressorKind::parse(&r.str()?)?;
            let cols = r.u32()? as usize;
            MultFamily::Approx42 {
                compressor: comp,
                approx_cols: cols,
            }
        }
        2 => MultFamily::LogOur,
        3 => MultFamily::Mitchell,
        4 => MultFamily::AdderTree,
        tag => bail!("unknown multiplier-family tag {tag}"),
    })
}

// Wire helpers (`put_*`, `Reader`) live in `crate::store::wire`, shared
// with the design-point record format.

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::layer_macs_per_image;

    pub(super) fn sample_plan() -> CompiledPlan {
        let macs = layer_macs_per_image();
        let families = [
            MultFamily::Exact,
            MultFamily::Approx42 {
                compressor: CompressorKind::Kong,
                approx_cols: 4,
            },
            MultFamily::LogOur,
            MultFamily::Exact,
        ];
        let energies = [2.5e-12, 2.1e-12, 1.4e-12, 2.5e-12];
        let layers: Vec<LayerPlan> = (0..N_LAYERS)
            .map(|i| LayerPlan {
                layer: LAYER_NAMES[i].to_string(),
                family: families[i].clone(),
                energy_per_op_j: energies[i],
                macs_per_image: macs[i],
                solo_drop: if i == 0 || i == 3 { 0.0 } else { 0.01 },
            })
            .collect();
        let total_macs: u64 = macs.iter().sum();
        let plan_energy: f64 = layers
            .iter()
            .map(|l| l.macs_per_image as f64 * l.energy_per_op_j)
            .sum();
        CompiledPlan {
            name: "unit".into(),
            bits: 8,
            budget_drop: 0.02,
            model_hash: 0x1234_5678_9abc_def0_0fed_cba9_8765_4321,
            calib_hash: 42,
            calib_n: 128,
            exact_top1: 1.0,
            plan_top1: 0.984375,
            exact_energy_per_image_j: total_macs as f64 * 2.5e-12,
            plan_energy_per_image_j: plan_energy,
            layers,
        }
    }

    #[test]
    fn roundtrip_bit_identical() {
        let plan = sample_plan();
        let back = CompiledPlan::decode(&plan.encode()).unwrap();
        assert_eq!(back, plan);
        assert_eq!(back.plan_top1.to_bits(), plan.plan_top1.to_bits());
    }

    #[test]
    fn derived_quantities() {
        let plan = sample_plan();
        assert!((plan.drop_vs_exact() - (1.0 - 0.984375)).abs() < 1e-12);
        assert!(plan.energy_saving() > 0.0 && plan.energy_saving() < 1.0);
        assert!(plan.energy_per_op_j() > 0.0);
        assert_eq!(
            plan.assignment_label(),
            "exact,appro42[kongx4],log-our,exact"
        );
    }

    #[test]
    fn corruption_and_truncation_detected() {
        let bytes = sample_plan().encode();
        for cut in [0, 7, 20, bytes.len() - 9, bytes.len() - 1] {
            assert!(CompiledPlan::decode(&bytes[..cut]).is_err(), "cut {cut}");
        }
        for byte in (0..bytes.len()).step_by(11) {
            let mut b = bytes.clone();
            b[byte] ^= 0x10;
            assert!(CompiledPlan::decode(&b).is_err(), "flip at {byte}");
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!(
            "openacm_plan_unit_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.acmplan");
        let plan = sample_plan();
        plan.save(&path).unwrap();
        assert_eq!(CompiledPlan::load(&path).unwrap(), plan);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shared_families_share_luts() {
        let plan = sample_plan(); // conv1 and fc2 are both exact
        let luts = plan.build_luts();
        assert!(Arc::ptr_eq(&luts.layers[0], &luts.layers[3]));
        assert!(!Arc::ptr_eq(&luts.layers[0], &luts.layers[1]));
        // The uniform constructor shares one table four ways.
        let u = PlanLuts::uniform(Arc::new(vec![0i32; 65536]));
        assert!(Arc::ptr_eq(&u.layers[0], &u.layers[3]));
    }
}
