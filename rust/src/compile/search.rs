//! The accuracy-budgeted search: per-layer sensitivity profiling, greedy
//! energy descent over the joint assignment, and pairwise-swap local
//! refinement — every accepted candidate validated by its *true* measured
//! top-1 on the calibration set, every measurement memoized in the
//! design-point store.
//!
//! ## Algorithm
//!
//! 1. **Candidate space** — the full multiplier family space at the LUT
//!    width ([`candidates`]): exact, both logarithmic designs, and every
//!    (compressor, `approx_cols`) combination. Each candidate gets an
//!    energy-per-multiply estimate from the PPA engine (store-backed,
//!    [`analyze_macro_cached`]) and a behavioral int8 LUT.
//! 2. **Sensitivity profiling** — for each (layer, candidate): swap only
//!    that layer's LUT through [`QuantCnn::forward_batch_hetero`] on the
//!    calibration set and record the top-1 drop vs the all-exact baseline.
//! 3. **Greedy energy descent** — from all-exact, repeatedly apply the
//!    single-layer move with the largest energy saving whose *measured*
//!    joint accuracy stays within budget. Moves whose solo drop already
//!    exceeds the budget are pruned (monotonicity heuristic — pruning only
//!    skips candidates, it can never admit a budget violation, because
//!    every accepted move is validated by a real joint measurement).
//! 4. **Pairwise refinement** — bounded passes over layer pairs, trying
//!    joint two-layer swaps drawn from per-layer shortlists (cheapest
//!    configs + exact + current): accept the best strictly-energy-
//!    improving, budget-respecting swap. This escapes greedy local minima
//!    where one layer must be *upgraded* to afford a bigger downgrade
//!    elsewhere.
//!
//! ## Memoization
//!
//! Every accuracy measurement is keyed on
//! `model hash × assignment × calibration hash` (domain
//! `"compile-accuracy/1"`) and persisted as an
//! [`crate::store::AccuracyStats`] record, so a repeated compile — or a
//! budget sweep sharing one store — is served from disk. The search is
//! deterministic, so a warm re-compile replays the identical key sequence
//! and returns a bit-identical plan.
//!
//! ## Incremental evaluation (suffix replay)
//!
//! A fresh measurement no longer pays a full calibration forward. The
//! engine pins the all-exact baseline as a [`ReferenceChain`] (per-layer
//! checkpoints + raw GEMM accumulators) and keeps a small LRU of prefix
//! checkpoints keyed on `model hash × calibration hash × per-layer family
//! prefix`; measuring an assignment then
//!
//! 1. **canonicalizes** it by LUT content (families whose int8 LUT is
//!    byte-identical — e.g. `addertree` vs `exact` — share one
//!    measurement, served without any forward);
//! 2. resumes from the **deepest cached prefix** (the pinned all-exact
//!    chain for exact prefixes — the case every sensitivity probe hits —
//!    or the LRU, which greedy/refinement trials populate with the
//!    current assignment's prefixes as a side effect of measuring);
//! 3. replays plain stages through the **last non-exact layer**, then
//!    switches to **sparse linear delta replay**
//!    ([`QuantCnn::delta_resume_exact`]) for the all-exact suffix, whose
//!    cost scales with the activation entries the swap actually changed.
//!
//! Every mechanism reuses only values proven byte-identical (checkpoint
//! prefixes, LUT contents, exact-LUT linearity), so measured accuracies —
//! and therefore the emitted plan and every store record — are
//! bit-identical to the non-incremental path (`--no-incremental`, or
//! [`CompileOptions::incremental`] = false, keeps that path available for
//! A/B debugging). Probe batches arrive grouped by earliest-changed layer
//! by construction: the sensitivity loops vary the candidate within one
//! layer before moving on, and greedy/refinement trials share the current
//! assignment's prefix, which the LRU retains between probes. Suffix
//! GEMMs run on the existing thread pool ([`parallel_map`] row tiles).
//! [`SearchStats`] counts replayed vs cold-equivalent MACs;
//! `benches/compile.rs` tracks the reduction across PRs.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;

use crate::config::spec::{CompressorKind, MacroSpec, MultFamily};
use crate::dse::sweep::{candidates, DSE_SEED};
use crate::mult::behavioral::int8_lut;
use crate::nn::eval::argmax;
use crate::nn::model::{
    layer_macs_per_image, synthetic_images, BatchCheckpoint, LayerLuts, QuantCnn, ReferenceChain,
    IMG, LAYER_NAMES, N_LAYERS,
};
use crate::ppa::report::analyze_macro_cached;
use crate::store::{AccuracyStats, DesignPointRecord, DesignPointStore, Key128, KeyBuilder};
use crate::util::threadpool::parallel_map;

use super::plan::{CompiledPlan, LayerPlan};

/// Comparison slack for budget checks (accuracy values are exact k/n
/// fractions; this only absorbs the final f64 subtraction's rounding).
const BUDGET_EPS: f64 = 1e-9;

/// Knobs of one compile run.
#[derive(Clone, Debug)]
pub struct CompileOptions {
    /// Allowed top-1 drop vs the all-exact baseline, as a fraction
    /// (0.005 = 0.5%).
    pub budget_drop: f64,
    /// Calibration-set size (synthetic sets only; ignored for
    /// caller-provided sets).
    pub calib_n: usize,
    /// Seed for the synthetic calibration set / model.
    pub seed: u64,
    /// Thread budget for the calibration forward passes.
    pub threads: usize,
    /// SRAM rows of the macro geometry behind the energy model.
    pub rows: usize,
    /// Workload size for the PPA energy characterization.
    pub ppa_ops: usize,
    /// Which layers the search may touch (unmasked layers stay exact) —
    /// smoke mode restricts to the two fc layers.
    pub layer_mask: [bool; N_LAYERS],
    /// Pairwise-refinement passes (0 disables refinement).
    pub refine_passes: usize,
    /// Per-layer shortlist size for pairwise refinement.
    pub shortlist: usize,
    /// Use the reduced smoke candidate space instead of the full family
    /// space.
    pub smoke_space: bool,
    /// Evaluate candidates incrementally (prefix checkpoints + suffix /
    /// delta replay). Off = the historical full-forward path; results are
    /// bit-identical either way (`openacm compile --no-incremental`).
    pub incremental: bool,
}

impl CompileOptions {
    /// Full-strength defaults at the given accuracy budget. The default
    /// seed matches `openacm serve`'s default, so an artifact-free
    /// compile → serve flow compiles for the same synthetic model it
    /// then serves.
    pub fn new(budget_drop: f64) -> CompileOptions {
        CompileOptions {
            budget_drop,
            calib_n: 256,
            seed: 42,
            threads: 1,
            rows: 16,
            ppa_ops: 1500,
            layer_mask: [true; N_LAYERS],
            refine_passes: 2,
            shortlist: 4,
            smoke_space: false,
            incremental: true,
        }
    }

    /// CI smoke configuration: tiny calibration set, reduced candidate
    /// space, and only the two fc layers searchable.
    pub fn smoke(budget_drop: f64) -> CompileOptions {
        CompileOptions {
            calib_n: 32,
            ppa_ops: 200,
            layer_mask: [false, false, true, true],
            refine_passes: 1,
            shortlist: 2,
            smoke_space: true,
            ..CompileOptions::new(budget_drop)
        }
    }
}

/// The labeled image set every candidate assignment is validated on.
pub struct CalibrationSet {
    /// `n * 256` bytes, 16×16 grayscale each.
    pub images: Vec<u8>,
    pub n: usize,
    /// One label per image.
    pub labels: Vec<usize>,
    /// Content hash over images + labels (part of every memoization key).
    pub hash: Key128,
}

impl CalibrationSet {
    /// From explicit images + labels (e.g. a real dataset snapshot).
    pub fn from_parts(images: Vec<u8>, labels: Vec<usize>) -> CalibrationSet {
        assert_eq!(images.len(), labels.len() * IMG * IMG);
        let label_bytes: Vec<u8> = labels.iter().map(|&l| l as u8).collect();
        let hash = KeyBuilder::new("compile-calib/1")
            .bytes(&images)
            .bytes(&label_bytes)
            .finish();
        CalibrationSet {
            n: labels.len(),
            images,
            labels,
            hash,
        }
    }

    /// Deterministic synthetic calibration set labeled by the *exact*
    /// multiplier's predictions on `model` — "accuracy" then reads as
    /// agreement with exact inference, and the all-exact baseline scores
    /// exactly 1.0.
    pub fn synthetic(model: &QuantCnn, n: usize, seed: u64, threads: usize) -> CalibrationSet {
        let images = synthetic_images(n, seed ^ 0x5EED_CA11);
        let exact = int8_lut(&MultFamily::Exact);
        let views: Vec<&[u8]> = images.chunks(IMG * IMG).collect();
        let labels = model
            .forward_batch(&exact, &views, threads)
            .iter()
            .map(|row| argmax(row))
            .collect();
        CalibrationSet::from_parts(images, labels)
    }

    /// Per-image 256-byte views.
    pub fn views(&self) -> Vec<&[u8]> {
        self.images.chunks(IMG * IMG).collect()
    }
}

/// One multiplier configuration a layer can be assigned.
#[derive(Clone)]
pub struct Candidate {
    pub family: MultFamily,
    /// Energy per multiply, J (PPA estimate at the compile geometry).
    pub energy_per_op_j: f64,
    /// The int8 product LUT the layer would execute through.
    pub lut: Arc<Vec<i32>>,
}

/// Build the candidate configurations: family space + PPA energy + LUT.
/// Candidate 0 is always the exact multiplier. Characterization runs one
/// family per worker (the same split the DSE sweep uses — results are
/// index-ordered and deterministic for any thread count), and PPA
/// analyses are store-backed, so repeated compiles (and DSE sweeps
/// sharing the store) pay for each family once.
pub fn candidate_space(opts: &CompileOptions, store: Option<&DesignPointStore>) -> Vec<Candidate> {
    let families: Vec<MultFamily> = if opts.smoke_space {
        vec![
            MultFamily::Exact,
            MultFamily::LogOur,
            MultFamily::Mitchell,
            MultFamily::default_approx(8),
            MultFamily::Approx42 {
                compressor: CompressorKind::Kong,
                approx_cols: 4,
            },
        ]
    } else {
        candidates(8)
    };
    assert!(
        matches!(families[0], MultFamily::Exact),
        "candidate 0 must be the exact multiplier"
    );
    parallel_map(families.len(), opts.threads, |i| {
        let family = families[i].clone();
        let spec = MacroSpec::new(
            &format!("compile_{}", family.name()),
            opts.rows,
            8,
            family.clone(),
        );
        let ppa = analyze_macro_cached(&spec, opts.ppa_ops, DSE_SEED, 1, store);
        Candidate {
            lut: Arc::new(int8_lut(&family)),
            family,
            energy_per_op_j: ppa.energy_per_op_j,
        }
    })
}

/// Candidate index → lowest candidate index with a byte-identical int8
/// LUT. Different family labels can compile to the same product table
/// (e.g. the adder-tree baseline is functionally the exact multiplier);
/// measurements of such twins are interchangeable bit-for-bit, so the
/// incremental engine evaluates one representative per content class.
fn canonical_map(cands: &[Candidate]) -> Vec<usize> {
    let mut canon: Vec<usize> = Vec::with_capacity(cands.len());
    for (i, c) in cands.iter().enumerate() {
        let rep = (0..i)
            .find(|&j| canon[j] == j && cands[j].lut == c.lut)
            .unwrap_or(i);
        canon.push(rep);
    }
    canon
}

/// Content hash of a quantized model: weights, scales and biases by exact
/// bit pattern — part of every memoization key, stored in the plan so a
/// served plan can be matched back to the model it was compiled for.
pub fn model_content_hash(model: &QuantCnn) -> Key128 {
    let mut kb = KeyBuilder::new("compile-model/1");
    for layer in [&model.conv1, &model.conv2, &model.fc1, &model.fc2] {
        let wq: Vec<u8> = layer.w_q.iter().map(|&v| v as u8).collect();
        kb.bytes(&wq);
        kb.f64(layer.w_scale as f64);
        kb.f64(layer.in_scale as f64);
        let bias: Vec<f64> = layer.bias.iter().map(|&b| b as f64).collect();
        kb.f64s(&bias);
    }
    kb.finish()
}

/// A per-layer assignment: candidate index per layer (0 = exact).
pub type Assignment = [usize; N_LAYERS];

/// Work counters of one compile run — the incremental evaluator's
/// headline numbers (`benches/compile.rs` asserts on the MAC reduction).
#[derive(Clone, Copy, Debug, Default)]
pub struct SearchStats {
    /// In-memory memo hits on the raw assignment (same memo the
    /// non-incremental engine keeps).
    pub memo_hits: u64,
    /// Design-point-store hits.
    pub store_hits: u64,
    /// Measurements neither the memo nor the store could serve.
    pub evaluations: u64,
    /// Evaluations served through LUT-content canonicalization without
    /// any forward (byte-identical LUTs ⇒ byte-identical measurement).
    pub free_probes: u64,
    /// GEMM MAC-equivalents this engine actually executed (stage GEMMs +
    /// sparse delta updates).
    pub replayed_macs: u64,
    /// MAC-equivalents the full-forward path would have executed for the
    /// same evaluations; `replayed_macs == full_macs` when incremental
    /// evaluation is off.
    pub full_macs: u64,
    /// Portion of `replayed_macs` executed as sparse linear delta
    /// updates.
    pub delta_macs: u64,
    /// Suffix replays that started from a cached prefix deeper than the
    /// shared depth-0 input checkpoint.
    pub prefix_hits: u64,
    /// All-exact reference-chain builds (one per engine, lazily).
    pub anchor_builds: u64,
}

impl SearchStats {
    /// How many times fewer MACs the engine replayed than the cold
    /// full-forward path would have (1.0 when incremental is off).
    pub fn mac_reduction(&self) -> f64 {
        if self.replayed_macs == 0 {
            return 1.0;
        }
        self.full_macs as f64 / self.replayed_macs as f64
    }
}

/// Entries the prefix LRU keeps. Checkpoints are a few hundred KiB per
/// calibration batch at the conv depths, so a dozen entries comfortably
/// cover the current assignment's prefix chain plus in-flight probes.
const PREFIX_CACHE_CAP: usize = 12;

/// Small LRU of prefix checkpoints, keyed on
/// `model hash × calibration hash × canonical family prefix`.
struct PrefixCache {
    cap: usize,
    /// Front = most recently used.
    entries: Vec<(Key128, Rc<BatchCheckpoint>)>,
}

impl PrefixCache {
    fn new(cap: usize) -> PrefixCache {
        PrefixCache {
            cap,
            entries: Vec::new(),
        }
    }

    fn get(&mut self, key: Key128) -> Option<Rc<BatchCheckpoint>> {
        let pos = self.entries.iter().position(|(k, _)| *k == key)?;
        let e = self.entries.remove(pos);
        let ck = Rc::clone(&e.1);
        self.entries.insert(0, e);
        Some(ck)
    }

    fn put(&mut self, key: Key128, ck: Rc<BatchCheckpoint>) {
        if let Some(pos) = self.entries.iter().position(|(k, _)| *k == key) {
            self.entries.remove(pos);
        }
        self.entries.insert(0, (key, ck));
        self.entries.truncate(self.cap);
    }
}

/// The pinned all-exact baseline: reference chain (prefix checkpoints at
/// every depth + raw accumulators) and its measured top-1.
struct Anchor {
    chain: ReferenceChain,
    top1: f64,
}

/// The search engine. Holds the model, calibration set, candidate space
/// and store handle for one compile run.
pub struct Compiler<'a> {
    model: &'a QuantCnn,
    model_hash: Key128,
    calib: &'a CalibrationSet,
    cands: Vec<Candidate>,
    /// Candidate index → lowest candidate index with a byte-identical
    /// LUT (content canonicalization; `canon[0] == 0` is the exact LUT).
    canon: Vec<usize>,
    macs: [u64; N_LAYERS],
    opts: CompileOptions,
    store: Option<&'a DesignPointStore>,
    /// In-memory measurement memo: the phases revisit assignments (a
    /// sensitivity trial is also greedy's first validation of that move,
    /// refinement passes retry combinations), and without it every revisit
    /// in a store-less run would pay a full calibration forward.
    evals: RefCell<HashMap<Assignment, f64>>,
    /// Canonical-assignment memo (incremental mode): raw assignments with
    /// byte-identical LUTs share one measured value.
    canon_evals: RefCell<HashMap<Assignment, f64>>,
    anchor: RefCell<Option<Anchor>>,
    prefixes: RefCell<PrefixCache>,
    stats: RefCell<SearchStats>,
    /// High-water mark of `stats` already mirrored into the registry
    /// (see [`Compiler::publish_stats`]).
    published: RefCell<SearchStats>,
}

impl<'a> Compiler<'a> {
    pub fn new(
        model: &'a QuantCnn,
        calib: &'a CalibrationSet,
        opts: CompileOptions,
        store: Option<&'a DesignPointStore>,
    ) -> Compiler<'a> {
        let cands = candidate_space(&opts, store);
        Compiler::assemble(model, calib, cands, opts, store)
    }

    /// Wire an engine around an explicit candidate space (tests use this
    /// to skip PPA characterization).
    fn assemble(
        model: &'a QuantCnn,
        calib: &'a CalibrationSet,
        cands: Vec<Candidate>,
        opts: CompileOptions,
        store: Option<&'a DesignPointStore>,
    ) -> Compiler<'a> {
        if opts.incremental {
            // Sparse delta replay leans on candidate 0's LUT being the
            // *linear* exact product (`lut[a][w] == a·w`); everything
            // downstream of a probe is reconstructed under that identity,
            // so verify it once up front (65536 integer compares).
            let lut = &cands[0].lut;
            assert_eq!(lut.len(), 65536);
            let linear = (0usize..256).all(|a| {
                let ai = (a as u8) as i8 as i32;
                (0usize..256).all(|b| {
                    let bi = (b as u8) as i8 as i32;
                    lut[(a << 8) | b] == ai * bi
                })
            });
            assert!(
                linear,
                "incremental evaluation requires candidate 0 to be the exact product LUT"
            );
        }
        let canon = canonical_map(&cands);
        Compiler {
            model,
            model_hash: model_content_hash(model),
            calib,
            cands,
            canon,
            macs: layer_macs_per_image(),
            opts,
            store,
            evals: RefCell::new(HashMap::new()),
            canon_evals: RefCell::new(HashMap::new()),
            anchor: RefCell::new(None),
            prefixes: RefCell::new(PrefixCache::new(PREFIX_CACHE_CAP)),
            stats: RefCell::new(SearchStats::default()),
            published: RefCell::new(SearchStats::default()),
        }
    }

    /// Work counters of this run so far.
    pub fn stats(&self) -> SearchStats {
        *self.stats.borrow()
    }

    /// Mirror this run's [`SearchStats`] into the process-wide registry
    /// as `compile.*` counters — the delta since the last publish, so
    /// repeated calls (and multiple compiles per process) accumulate
    /// without double-counting. [`Compiler::compile`] calls it once at
    /// the end; long-running drivers may call it mid-search.
    pub fn publish_stats(&self) {
        let now = self.stats();
        let mut last = self.published.borrow_mut();
        for (name, value) in [
            ("compile.memo_hits", now.memo_hits - last.memo_hits),
            ("compile.store_hits", now.store_hits - last.store_hits),
            ("compile.evaluations", now.evaluations - last.evaluations),
            ("compile.free_probes", now.free_probes - last.free_probes),
            ("compile.replayed_macs", now.replayed_macs - last.replayed_macs),
            ("compile.full_macs", now.full_macs - last.full_macs),
            ("compile.delta_macs", now.delta_macs - last.delta_macs),
            ("compile.prefix_hits", now.prefix_hits - last.prefix_hits),
            ("compile.anchor_builds", now.anchor_builds - last.anchor_builds),
        ] {
            if value > 0 {
                crate::obs::counter(name).add(value);
            }
        }
        *last = now;
    }

    /// The candidate configurations this run searches over.
    pub fn candidates(&self) -> &[Candidate] {
        &self.cands
    }

    fn assignment_label(&self, asg: &Assignment) -> String {
        asg.iter()
            .map(|&c| self.cands[c].family.name())
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Memoization key: model hash × assignment × calibration hash.
    fn assignment_key(&self, asg: &Assignment) -> Key128 {
        let mut kb = KeyBuilder::new("compile-accuracy/1");
        kb.key(self.model_hash).key(self.calib.hash).u32(8);
        for &c in asg.iter() {
            kb.str(&self.cands[c].family.name());
        }
        kb.finish()
    }

    /// Score per-image logits against the calibration labels.
    fn top1_of_logits(&self, logits: &[Vec<f32>]) -> f64 {
        let mut correct = 0usize;
        for (row, &label) in logits.iter().zip(&self.calib.labels) {
            if argmax(row) == label {
                correct += 1;
            }
        }
        correct as f64 / self.calib.n.max(1) as f64
    }

    /// The historical measurement path: one full calibration forward
    /// (kept verbatim as the `--no-incremental` A/B baseline and the
    /// incremental path's oracle).
    fn measure(&self, asg: &Assignment) -> f64 {
        let luts = LayerLuts {
            conv1: &self.cands[asg[0]].lut,
            conv2: &self.cands[asg[1]].lut,
            fc1: &self.cands[asg[2]].lut,
            fc2: &self.cands[asg[3]].lut,
        };
        let views = self.calib.views();
        let logits = self
            .model
            .forward_batch_hetero(&luts, &views, self.opts.threads);
        self.top1_of_logits(&logits)
    }

    /// MAC-equivalents of one full calibration forward.
    fn full_forward_macs(&self) -> u64 {
        self.calib.n as u64 * self.macs.iter().sum::<u64>()
    }

    /// A measurement neither the raw memo nor the store could serve.
    /// This is where the cold path pays a full calibration forward and
    /// the incremental engine replays a suffix instead.
    fn evaluate(&self, asg: &Assignment) -> f64 {
        let _probe = crate::obs::span("compile.probe");
        {
            let mut st = self.stats.borrow_mut();
            st.evaluations += 1;
            st.full_macs += self.full_forward_macs();
        }
        if !self.opts.incremental {
            let top1 = self.measure(asg);
            self.stats.borrow_mut().replayed_macs += self.full_forward_macs();
            return top1;
        }
        let casg = self.canon_asg(asg);
        if let Some(&top1) = self.canon_evals.borrow().get(&casg) {
            // A content twin was already measured: byte-identical LUTs
            // give a byte-identical forward, so the value transfers.
            self.stats.borrow_mut().free_probes += 1;
            return top1;
        }
        let top1 = self.measure_incremental(&casg);
        self.canon_evals.borrow_mut().insert(casg, top1);
        top1
    }

    /// Measured top-1 of an assignment on the calibration set — memoized
    /// in memory for this run and persistently in the store (bit-identical
    /// on a warm hit: the record stores the f64's exact bit pattern).
    pub fn measured_top1(&self, asg: &Assignment) -> f64 {
        if let Some(&top1) = self.evals.borrow().get(asg) {
            self.stats.borrow_mut().memo_hits += 1;
            return top1;
        }
        let top1 = match self.store {
            None => self.evaluate(asg),
            Some(store) => {
                let key = self.assignment_key(asg);
                let (rec, hit) = store.get_or_put_with(key, || DesignPointRecord {
                    family: format!("compile[{}]", self.assignment_label(asg)),
                    bits: 8,
                    n_ops: self.calib.n as u64,
                    seed: self.opts.seed,
                    accuracy: Some(AccuracyStats {
                        top1: self.evaluate(asg),
                        samples: self.calib.n as u64,
                    }),
                    ..Default::default()
                });
                if hit {
                    self.stats.borrow_mut().store_hits += 1;
                }
                match rec.accuracy {
                    Some(a) => a.top1,
                    None => self.evaluate(asg),
                }
            }
        };
        self.evals.borrow_mut().insert(*asg, top1);
        top1
    }

    /// Map an assignment to its LUT-content-canonical representative.
    fn canon_asg(&self, asg: &Assignment) -> Assignment {
        let mut out = *asg;
        for c in out.iter_mut() {
            *c = self.canon[*c];
        }
        out
    }

    /// In-memory key of a canonical prefix checkpoint.
    fn prefix_key(&self, prefix: &[usize]) -> Key128 {
        let mut kb = KeyBuilder::new("compile-prefix/1");
        kb.key(self.model_hash)
            .key(self.calib.hash)
            .u32(prefix.len() as u32);
        for &c in prefix {
            kb.str(&self.cands[c].family.name());
        }
        kb.finish()
    }

    /// Build (once) the pinned all-exact reference chain + per-image
    /// verdicts. Lazy: a fully store-warm compile never forwards at all,
    /// so it must not pay for an anchor either.
    fn build_anchor_if_needed(&self) {
        if self.anchor.borrow().is_some() {
            return;
        }
        let views = self.calib.views();
        let threads = self.opts.threads;
        let exact = LayerLuts::uniform(&self.cands[0].lut);
        let chain = self.model.reference_chain(&exact, &views, threads);
        let top1 = self.top1_of_logits(chain.logits());
        {
            let mut st = self.stats.borrow_mut();
            st.anchor_builds += 1;
            st.replayed_macs += self.full_forward_macs();
        }
        *self.anchor.borrow_mut() = Some(Anchor { chain, top1 });
    }

    /// Incremental measurement of a canonical assignment: resume from the
    /// deepest cached prefix, advance plain stages through the last
    /// non-exact layer, then delta-replay the all-exact suffix against
    /// the pinned anchor. Bit-identical to [`Compiler::measure`] — every
    /// reused value is byte-equal by construction.
    fn measure_incremental(&self, casg: &Assignment) -> f64 {
        self.build_anchor_if_needed();
        let anchor_slot = self.anchor.borrow();
        let anchor = anchor_slot.as_ref().expect("anchor just built");
        if *casg == [0usize; N_LAYERS] {
            // The baseline itself: its verdicts are the anchor's.
            return anchor.top1;
        }
        let bsz = self.calib.n as u64;
        // Delta replay is valid strictly after the last non-exact layer.
        let d_hi = (0..N_LAYERS)
            .rev()
            .find(|&l| casg[l] != 0)
            .expect("non-baseline assignment has a non-exact layer");
        // Deepest reusable prefix: the pinned anchor chain serves every
        // all-exact prefix (depth 0 — the shared input checkpoint — always
        // matches), the LRU serves prefixes recent probes replayed.
        let mut depth = 0usize;
        let mut cur_rc: Option<Rc<BatchCheckpoint>> = None;
        for d in (0..N_LAYERS).rev() {
            if casg[..d].iter().all(|&c| c == 0) {
                depth = d;
                break;
            }
            if let Some(ck) = self.prefixes.borrow_mut().get(self.prefix_key(&casg[..d])) {
                depth = d;
                cur_rc = Some(ck);
                break;
            }
        }
        if depth > 0 {
            self.stats.borrow_mut().prefix_hits += 1;
        }
        let threads = self.opts.threads;
        let mut replayed = 0u64;
        // Plain stage replay through the last non-exact layer (their LUTs
        // are arbitrary), inserting each new prefix into the LRU.
        while depth <= d_hi && depth < N_LAYERS - 1 {
            let next = {
                let ck: &BatchCheckpoint = match &cur_rc {
                    Some(rc) => rc,
                    None => anchor.chain.checkpoint(depth),
                };
                let lut = &self.cands[casg[depth]].lut;
                self.model.advance_checkpoint(ck, lut, threads)
            };
            replayed += bsz * self.macs[depth];
            depth += 1;
            let rc = Rc::new(next);
            self.prefixes
                .borrow_mut()
                .put(self.prefix_key(&casg[..depth]), Rc::clone(&rc));
            cur_rc = Some(rc);
        }
        let cur_ck: &BatchCheckpoint = match &cur_rc {
            Some(rc) => rc,
            None => anchor.chain.checkpoint(depth),
        };
        let logits = if d_hi == N_LAYERS - 1 {
            // The final layer itself is non-exact: plain finish.
            replayed += bsz * self.macs[N_LAYERS - 1];
            let lut = &self.cands[casg[N_LAYERS - 1]].lut;
            self.model.finish_checkpoint(cur_ck, lut, threads)
        } else {
            // Everything from `depth` on is the exact multiplier: sparse
            // linear delta replay against the anchor's accumulators.
            let (logits, dmacs) = self.model.delta_resume_exact(&anchor.chain, cur_ck);
            replayed += dmacs;
            self.stats.borrow_mut().delta_macs += dmacs;
            logits
        };
        self.stats.borrow_mut().replayed_macs += replayed;
        self.top1_of_logits(&logits)
    }

    /// Estimated energy per image of an assignment, J.
    pub fn plan_energy(&self, asg: &Assignment) -> f64 {
        (0..N_LAYERS)
            .map(|l| self.macs[l] as f64 * self.cands[asg[l]].energy_per_op_j)
            .sum()
    }

    /// Phase (a): solo sensitivity per (layer, candidate) — the top-1 drop
    /// when only that layer runs that candidate. Unmasked layers and the
    /// exact candidate read 0.
    pub fn sensitivity(&self, exact_top1: f64) -> Vec<Vec<f64>> {
        let _span = crate::obs::span("compile.sensitivity");
        let mut out = vec![vec![0.0f64; self.cands.len()]; N_LAYERS];
        for l in 0..N_LAYERS {
            if !self.opts.layer_mask[l] {
                continue;
            }
            for c in 1..self.cands.len() {
                let mut asg: Assignment = [0; N_LAYERS];
                asg[l] = c;
                out[l][c] = exact_top1 - self.measured_top1(&asg);
            }
        }
        out
    }

    /// Pairwise-refinement shortlist around a layer's current candidate:
    /// exact + current + the cheapest `shortlist` energy-saving configs.
    fn shortlist(&self, current: usize) -> Vec<usize> {
        let exact_e = self.cands[0].energy_per_op_j;
        let mut cheap: Vec<usize> = (1..self.cands.len())
            .filter(|&c| self.cands[c].energy_per_op_j < exact_e)
            .collect();
        cheap.sort_by(|&a, &b| {
            self.cands[a]
                .energy_per_op_j
                .total_cmp(&self.cands[b].energy_per_op_j)
                .then(a.cmp(&b))
        });
        cheap.truncate(self.opts.shortlist);
        let mut out = vec![0, current];
        out.extend(cheap);
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Run phases (a)–(c) and assemble the plan artifact. Deterministic
    /// for a given (model, calibration set, options) — thread counts only
    /// parallelize bit-identical forwards.
    pub fn compile(&self) -> CompiledPlan {
        let exact_asg: Assignment = [0; N_LAYERS];
        let exact_top1 = self.measured_top1(&exact_asg);
        let sens = self.sensitivity(exact_top1);
        let budget = self.opts.budget_drop;

        // (b) Greedy energy descent: always apply the largest-saving move
        // whose measured joint accuracy stays within budget. `banned`
        // records (layer, candidate) moves that failed validation — as
        // the assignment only ever gets *more* approximate, a failed move
        // can only fail harder later (the same monotonicity heuristic the
        // sensitivity pruning uses).
        let greedy_span = crate::obs::span("compile.greedy");
        let mut cur = exact_asg;
        let mut banned = vec![vec![false; self.cands.len()]; N_LAYERS];
        loop {
            let mut moves: Vec<(f64, usize, usize)> = Vec::new();
            for l in 0..N_LAYERS {
                if !self.opts.layer_mask[l] {
                    continue;
                }
                let cur_e = self.cands[cur[l]].energy_per_op_j;
                for c in 0..self.cands.len() {
                    if c == cur[l] || banned[l][c] {
                        continue;
                    }
                    let saving = (cur_e - self.cands[c].energy_per_op_j) * self.macs[l] as f64;
                    if saving <= 0.0 {
                        continue;
                    }
                    if sens[l][c] > budget + BUDGET_EPS {
                        banned[l][c] = true;
                        continue;
                    }
                    moves.push((saving, l, c));
                }
            }
            moves.sort_by(|a, b| {
                b.0.total_cmp(&a.0)
                    .then(a.1.cmp(&b.1))
                    .then(a.2.cmp(&b.2))
            });
            let mut accepted = false;
            for &(_, l, c) in &moves {
                let mut trial = cur;
                trial[l] = c;
                let drop = exact_top1 - self.measured_top1(&trial);
                if drop <= budget + BUDGET_EPS {
                    cur = trial;
                    accepted = true;
                    break;
                }
                banned[l][c] = true;
            }
            if !accepted {
                break;
            }
        }
        drop(greedy_span);

        // (c) Pairwise refinement: best strictly-energy-improving joint
        // two-layer swap within budget, up to `refine_passes` rounds.
        let refine_span = crate::obs::span("compile.refine");
        for _ in 0..self.opts.refine_passes {
            let cur_energy = self.plan_energy(&cur);
            let mut best: Option<(f64, Assignment)> = None;
            for i in 0..N_LAYERS {
                if !self.opts.layer_mask[i] {
                    continue;
                }
                for j in (i + 1)..N_LAYERS {
                    if !self.opts.layer_mask[j] {
                        continue;
                    }
                    for &ci in &self.shortlist(cur[i]) {
                        for &cj in &self.shortlist(cur[j]) {
                            if ci == cur[i] && cj == cur[j] {
                                continue;
                            }
                            let mut trial = cur;
                            trial[i] = ci;
                            trial[j] = cj;
                            let e = self.plan_energy(&trial);
                            if e >= cur_energy * (1.0 - 1e-9) {
                                continue;
                            }
                            if best.as_ref().is_some_and(|&(be, _)| e >= be) {
                                continue;
                            }
                            let drop = exact_top1 - self.measured_top1(&trial);
                            if drop <= budget + BUDGET_EPS {
                                best = Some((e, trial));
                            }
                        }
                    }
                }
            }
            match best {
                Some((_, trial)) => cur = trial,
                None => break,
            }
        }
        drop(refine_span);

        let plan_top1 = self.measured_top1(&cur);
        self.publish_stats();
        let layers: Vec<LayerPlan> = (0..N_LAYERS)
            .map(|l| LayerPlan {
                layer: LAYER_NAMES[l].to_string(),
                family: self.cands[cur[l]].family.clone(),
                energy_per_op_j: self.cands[cur[l]].energy_per_op_j,
                macs_per_image: self.macs[l],
                solo_drop: sens[l][cur[l]],
            })
            .collect();
        CompiledPlan {
            name: "plan".into(),
            bits: 8,
            budget_drop: budget,
            model_hash: self.model_hash.0,
            calib_hash: self.calib.hash.0,
            calib_n: self.calib.n as u64,
            exact_top1,
            plan_top1,
            exact_energy_per_image_j: self.plan_energy(&exact_asg),
            plan_energy_per_image_j: self.plan_energy(&cur),
            layers,
        }
    }
}

/// One-call front end: build the candidate space, search under the
/// budget, return the plan. See [`Compiler`] for the phases.
pub fn compile_budgeted(
    model: &QuantCnn,
    calib: &CalibrationSet,
    opts: &CompileOptions,
    store: Option<&DesignPointStore>,
) -> CompiledPlan {
    Compiler::new(model, calib, opts.clone(), store).compile()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exact_lut() -> Vec<i32> {
        let mut lut = vec![0i32; 65536];
        for a in -128i32..=127 {
            for b in -128i32..=127 {
                lut[(((a as u8) as usize) << 8) | ((b as u8) as usize)] = a * b;
            }
        }
        lut
    }

    /// A Compiler over a synthetic candidate space (no PPA, no behavioral
    /// LUT builds): candidate 0 = exact product at 3 pJ, candidate 1 = an
    /// all-zero LUT at 1 pJ (cheap but wrecks accuracy), candidate 2 = the
    /// exact product again at 2 pJ (a "free" saving). Family labels are
    /// only key material here.
    fn tiny_compiler<'a>(
        model: &'a QuantCnn,
        calib: &'a CalibrationSet,
        opts: CompileOptions,
        store: Option<&'a DesignPointStore>,
    ) -> Compiler<'a> {
        let exact = Arc::new(exact_lut());
        let cands = vec![
            Candidate {
                family: MultFamily::Exact,
                energy_per_op_j: 3e-12,
                lut: Arc::clone(&exact),
            },
            Candidate {
                family: MultFamily::Mitchell,
                energy_per_op_j: 1e-12,
                lut: Arc::new(vec![0i32; 65536]),
            },
            Candidate {
                family: MultFamily::LogOur,
                energy_per_op_j: 2e-12,
                lut: exact,
            },
        ];
        Compiler::assemble(model, calib, cands, opts, store)
    }

    fn calib_for(model: &QuantCnn, n: usize) -> CalibrationSet {
        // Label with the same exact LUT the tiny candidate space uses.
        let images = synthetic_images(n, 77);
        let lut = exact_lut();
        let views: Vec<&[u8]> = images.chunks(IMG * IMG).collect();
        let labels = model
            .forward_batch(&lut, &views, 1)
            .iter()
            .map(|row| argmax(row))
            .collect();
        CalibrationSet::from_parts(images, labels)
    }

    #[test]
    fn zero_budget_takes_free_savings_and_never_loses_accuracy() {
        let model = QuantCnn::random(3);
        let calib = calib_for(&model, 8);
        let opts = CompileOptions {
            budget_drop: 0.0,
            refine_passes: 1,
            ..CompileOptions::new(0.0)
        };
        let c = tiny_compiler(&model, &calib, opts, None);
        let plan = c.compile();
        // Labels are the exact LUT's own predictions, so all-exact scores
        // exactly 1.0 — and a zero budget means the plan must too: every
        // accepted move was validated at drop == 0.
        assert_eq!(plan.exact_top1, 1.0);
        assert_eq!(plan.plan_top1, 1.0);
        // Candidate 2 carries the identical exact-product LUT at 2/3 the
        // energy: a guaranteed-free saving on every layer, so the plan
        // must save at least 1/3 regardless of how the zero-LUT candidate
        // scores.
        assert!(plan.plan_energy_per_image_j < plan.exact_energy_per_image_j);
        assert!(plan.energy_saving() >= 1.0 / 3.0 - 1e-9);
    }

    #[test]
    fn layer_mask_pins_unmasked_layers_to_exact() {
        let model = QuantCnn::random(3);
        let calib = calib_for(&model, 4);
        let opts = CompileOptions {
            layer_mask: [false, false, true, true],
            refine_passes: 1,
            ..CompileOptions::new(1.0)
        };
        let c = tiny_compiler(&model, &calib, opts, None);
        let plan = c.compile();
        assert_eq!(plan.layers[0].family, MultFamily::Exact);
        assert_eq!(plan.layers[1].family, MultFamily::Exact);
        // With a 100% budget even the zero LUT is admissible on the two
        // searchable layers — the cheapest candidate wins there.
        assert_eq!(plan.layers[2].family, MultFamily::Mitchell);
        assert_eq!(plan.layers[3].family, MultFamily::Mitchell);
    }

    #[test]
    fn memoized_recompile_is_warm_and_bit_identical() {
        let dir = std::env::temp_dir().join(format!(
            "openacm_compile_memo_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = DesignPointStore::open(&dir).unwrap();
        let model = QuantCnn::random(9);
        let calib = calib_for(&model, 8);
        let opts = CompileOptions {
            budget_drop: 0.0,
            refine_passes: 1,
            ..CompileOptions::new(0.0)
        };
        let cold =
            tiny_compiler(&model, &calib, opts.clone(), Some(&store)).compile();
        let before = store.stats();
        let warm = tiny_compiler(&model, &calib, opts, Some(&store)).compile();
        let delta = store.stats().since(&before);
        assert_eq!(warm, cold, "warm compile must replay bit-identically");
        assert_eq!(delta.misses, 0, "second compile must be fully store-warm");
        assert!(delta.hits > 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn incremental_and_full_paths_produce_identical_plans_and_bytes() {
        // The acceptance criterion in miniature: same model, calibration
        // set, budget and seed — the incremental engine's plan must be
        // byte-identical to the full-forward engine's.
        let model = QuantCnn::random(5);
        let calib = calib_for(&model, 8);
        for budget in [0.0, 0.25] {
            let inc_opts = CompileOptions {
                budget_drop: budget,
                refine_passes: 1,
                ..CompileOptions::new(budget)
            };
            let full_opts = CompileOptions {
                incremental: false,
                ..inc_opts.clone()
            };
            let c_inc = tiny_compiler(&model, &calib, inc_opts, None);
            let c_full = tiny_compiler(&model, &calib, full_opts, None);
            let plan_inc = c_inc.compile();
            let plan_full = c_full.compile();
            assert_eq!(plan_inc, plan_full, "budget {budget}");
            // And the serialized artifacts match byte-for-byte.
            let dir = std::env::temp_dir().join(format!(
                "openacm_incr_ab_{}_{:?}_{budget}",
                std::process::id(),
                std::thread::current().id()
            ));
            std::fs::create_dir_all(&dir).unwrap();
            let pa = dir.join("inc.acmplan");
            let pb = dir.join("full.acmplan");
            plan_inc.save(&pa).unwrap();
            plan_full.save(&pb).unwrap();
            assert_eq!(
                std::fs::read(&pa).unwrap(),
                std::fs::read(&pb).unwrap(),
                "artifact bytes (budget {budget})"
            );
            std::fs::remove_dir_all(&dir).unwrap();
            // The incremental engine must have done strictly less GEMM
            // work for the same evaluations, and the full engine exactly
            // the cold amount.
            let si = c_inc.stats();
            let sf = c_full.stats();
            assert_eq!(si.evaluations, sf.evaluations, "same fresh evals");
            assert_eq!(sf.replayed_macs, sf.full_macs, "full path replays all");
            assert!(
                si.replayed_macs < si.full_macs,
                "incremental must replay fewer MACs: {} vs {}",
                si.replayed_macs,
                si.full_macs
            );
        }
    }

    #[test]
    fn canonicalization_serves_content_twins_without_forwards() {
        // Candidate 2 carries the exact LUT bytes under another family
        // label: measuring it must be a free probe, not a forward.
        let model = QuantCnn::random(8);
        let calib = calib_for(&model, 4);
        let c = tiny_compiler(&model, &calib, CompileOptions::new(0.0), None);
        let exact_top1 = c.measured_top1(&[0, 0, 0, 0]);
        let twin_top1 = c.measured_top1(&[0, 0, 0, 2]);
        assert_eq!(exact_top1.to_bits(), twin_top1.to_bits());
        let st = c.stats();
        assert_eq!(st.free_probes, 1);
        // Only the anchor build ran a forward-equivalent.
        assert_eq!(st.anchor_builds, 1);
        assert_eq!(st.replayed_macs, st.full_macs / 2);
    }

    #[test]
    fn sensitivity_probes_replay_only_suffixes() {
        let model = QuantCnn::random(6);
        let calib = calib_for(&model, 4);
        let c = tiny_compiler(&model, &calib, CompileOptions::new(1.0), None);
        let exact_top1 = c.measured_top1(&[0, 0, 0, 0]);
        let _sens = c.sensitivity(exact_top1);
        let st = c.stats();
        // Baseline + 2 probe candidates × 4 layers (candidate 2 probes
        // are free via canonicalization).
        assert_eq!(st.evaluations, 9);
        assert_eq!(st.free_probes, 4);
        assert!(
            st.replayed_macs < st.full_macs / 3,
            "sensitivity must replay under a third of cold MACs: {} vs {}",
            st.replayed_macs,
            st.full_macs
        );
    }

    #[test]
    fn prefix_cache_evicts_lru_and_moves_hits_to_front() {
        let model = QuantCnn::random(1);
        let images = synthetic_images(1, 1);
        let views: Vec<&[u8]> = images.chunks(IMG * IMG).collect();
        let ck = Rc::new(model.input_checkpoint(&views));
        let mut cache = PrefixCache::new(2);
        let key = |v: u32| KeyBuilder::new("test").u32(v).finish();
        cache.put(key(1), Rc::clone(&ck));
        cache.put(key(2), Rc::clone(&ck));
        assert!(cache.get(key(1)).is_some()); // 1 becomes MRU
        cache.put(key(3), Rc::clone(&ck)); // evicts 2 (LRU)
        assert!(cache.get(key(2)).is_none());
        assert!(cache.get(key(1)).is_some());
        assert!(cache.get(key(3)).is_some());
    }

    #[test]
    fn assignment_keys_separate_models_calibsets_and_assignments() {
        let m1 = QuantCnn::random(1);
        let m2 = QuantCnn::random(2);
        let c1 = calib_for(&m1, 2);
        let c2 = calib_for(&m2, 2);
        let opts = CompileOptions::new(0.0);
        let a = tiny_compiler(&m1, &c1, opts.clone(), None);
        let b = tiny_compiler(&m2, &c1, opts.clone(), None);
        let c = tiny_compiler(&m1, &c2, opts, None);
        let asg: Assignment = [0, 1, 2, 0];
        let asg2: Assignment = [0, 2, 1, 0];
        assert_ne!(a.assignment_key(&asg), b.assignment_key(&asg), "model");
        assert_ne!(a.assignment_key(&asg), c.assignment_key(&asg), "calib");
        assert_ne!(a.assignment_key(&asg), a.assignment_key(&asg2), "order");
    }
}
