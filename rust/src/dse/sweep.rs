//! Configuration sweep: enumerate multiplier configurations and score
//! accuracy (NMED, exhaustive at 8 bits) against energy/area from the PPA
//! engine — one point per candidate design.

use crate::config::spec::{CompressorKind, MacroSpec, MultFamily};
use crate::mult::error_metrics;
use crate::ppa::report::analyze_macro_cached;
use crate::store::DesignPointStore;
use crate::util::threadpool::parallel_map;

/// The fixed workload seed shared by every candidate (and therefore part
/// of every design-point key).
pub const DSE_SEED: u64 = 0xD5E;

/// One evaluated design point.
#[derive(Clone, Debug)]
pub struct DsePoint {
    pub label: String,
    pub family: MultFamily,
    /// Accuracy loss proxy: NMED of the multiplier (0 = exact).
    pub nmed: f64,
    /// Energy per multiply, J.
    pub energy_per_op_j: f64,
    /// Logic area, µm².
    pub logic_area_um2: f64,
    /// Relative energy vs the exact design (1.0 = exact).
    pub energy_ratio: f64,
}

/// The candidate set for one bit width: exact + adder-tree + both log
/// families + every (compressor, column-budget) combination.
pub fn candidates(bits: usize) -> Vec<MultFamily> {
    let mut out = vec![
        MultFamily::Exact,
        MultFamily::AdderTree,
        MultFamily::LogOur,
        MultFamily::Mitchell,
    ];
    // Column budgets: quarter, half, three-quarter, full product width.
    let budgets = [bits / 2, bits, 3 * bits / 2, 2 * bits];
    for &k in CompressorKind::all_approx() {
        for &cols in &budgets {
            if cols == 0 {
                continue;
            }
            out.push(MultFamily::Approx42 {
                compressor: k,
                approx_cols: cols,
            });
        }
    }
    out
}

/// Evaluate every candidate at the given macro geometry. Parallel over
/// candidates; deterministic (seeded workload shared across candidates).
pub fn sweep_configs(rows: usize, bits: usize, n_ops: usize, threads: usize) -> Vec<DsePoint> {
    sweep_configs_cached(rows, bits, n_ops, threads, None)
}

/// [`sweep_configs`] backed by the design-point store: every candidate's
/// PPA analysis and error characterization consult the store before
/// simulating and write back on a miss, so a repeated sweep (or one
/// overlapping an earlier sweep at a different row count — error records
/// are geometry-independent) is served from disk. Results are bit-identical
/// to the uncached path; hit/miss accounting is on `store.stats()`.
pub fn sweep_configs_cached(
    rows: usize,
    bits: usize,
    n_ops: usize,
    threads: usize,
    store: Option<&DesignPointStore>,
) -> Vec<DsePoint> {
    let cands = candidates(bits);
    let points: Vec<DsePoint> = parallel_map(cands.len(), threads, |i| {
        let family = cands[i].clone();
        let spec = MacroSpec::new(
            &format!("dse_{}", family.name()),
            rows,
            bits,
            family.clone(),
        );
        let ppa = analyze_macro_cached(&spec, n_ops, DSE_SEED, 1, store);
        let nmed = match &family {
            MultFamily::Exact | MultFamily::AdderTree => 0.0,
            f => {
                if bits <= 10 {
                    // Characterize the *netlist* on the bit-parallel engine —
                    // the same gates the PPA model just costed. Single-threaded
                    // here because the outer parallel_map already owns the
                    // cores (one worker per design point).
                    error_metrics::exhaustive_netlist_cached(f, bits, 1, store).nmed
                } else {
                    error_metrics::sampled_cached(f, bits, 20_000, DSE_SEED, store).nmed
                }
            }
        };
        DsePoint {
            label: family.name(),
            family,
            nmed,
            energy_per_op_j: ppa.energy_per_op_j,
            logic_area_um2: ppa.logic_area_um2,
            energy_ratio: 0.0, // filled below
        }
    });
    let exact_energy = points
        .iter()
        .find(|p| matches!(p.family, MultFamily::Exact))
        .map(|p| p.energy_per_op_j)
        .unwrap_or(1.0);
    points
        .into_iter()
        .map(|mut p| {
            p.energy_ratio = p.energy_per_op_j / exact_energy;
            p
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidate_set_covers_all_families() {
        let c = candidates(8);
        assert!(c.iter().any(|f| matches!(f, MultFamily::Exact)));
        assert!(c.iter().any(|f| matches!(f, MultFamily::LogOur)));
        let approx_count = c
            .iter()
            .filter(|f| matches!(f, MultFamily::Approx42 { .. }))
            .count();
        assert_eq!(approx_count, 6 * 4);
    }

    #[test]
    #[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
    fn sweep_small_produces_scored_points() {
        // Tiny sweep (few ops) to stay fast in tests.
        let pts = sweep_configs(16, 8, 300, 2);
        assert!(pts.len() > 10);
        let exact = pts
            .iter()
            .find(|p| matches!(p.family, MultFamily::Exact))
            .unwrap();
        assert_eq!(exact.nmed, 0.0);
        assert!((exact.energy_ratio - 1.0).abs() < 1e-9);
        // Some approximate design must save energy.
        assert!(
            pts.iter().any(|p| p.energy_ratio < 0.95 && p.nmed > 0.0),
            "no energy-saving approximate point found"
        );
    }
}
