//! `openacm dse` — accuracy-energy design-space exploration.

use anyhow::Result;

use super::pareto::{pareto_front, select_under_constraint};
use super::sweep::sweep_configs_cached;
use crate::bench::harness::{sci, Table};
use crate::util::cli::Args;
use crate::util::threadpool::ThreadPool;

pub fn cmd_dse(args: &Args) -> Result<()> {
    let rows = args.usize_or("rows", 16)?;
    let bits = args.usize_or("word-bits", 8)?;
    let n_ops = args.usize_or("ops", 1500)?;
    let threads = args.usize_or("threads", ThreadPool::default_parallelism())?;
    let budget = args.f64_or("nmed-budget", 1e-3)?;
    let store = crate::store::cli::store_from_args(args)?;

    eprintln!("sweeping {} candidates at {rows}x{bits}...", super::sweep::candidates(bits).len());
    let points = sweep_configs_cached(rows, bits, n_ops, threads, store.as_ref());
    let front = pareto_front(&points);

    let mut t = Table::new(
        "DSE: accuracy-energy Pareto frontier",
        &["Design", "NMED", "Energy/op (J)", "vs exact", "Logic (um2)"],
    );
    for p in &front {
        t.row(&[
            p.label.clone(),
            if p.nmed == 0.0 {
                "exact".into()
            } else {
                sci(p.nmed)
            },
            sci(p.energy_per_op_j),
            format!("{:.0}%", p.energy_ratio * 100.0),
            format!("{:.0}", p.logic_area_um2),
        ]);
    }
    t.print();

    match select_under_constraint(&points, budget) {
        Some(best) => println!(
            "\nselected under NMED <= {budget:.1e}: {} ({:.0}% of exact energy)",
            best.label,
            best.energy_ratio * 100.0
        ),
        None => println!("\nno design meets NMED <= {budget:.1e}"),
    }
    if let Some(store) = &store {
        println!("store {}: {}", store.root().display(), store.stats().summary());
    }
    Ok(())
}
