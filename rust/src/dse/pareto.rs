//! Pareto-frontier extraction over (accuracy loss, energy) and constrained
//! selection ("best energy under an NMED budget" — the compiler's
//! accuracy-constrained selection knob, paper §III-A).

use super::sweep::DsePoint;

/// Points not dominated in (nmed, energy): a point dominates another if it
/// is no worse in both and strictly better in one. Returned sorted by nmed.
pub fn pareto_front(points: &[DsePoint]) -> Vec<DsePoint> {
    let mut front: Vec<DsePoint> = Vec::new();
    for p in points {
        let dominated = points.iter().any(|q| {
            (q.nmed < p.nmed && q.energy_per_op_j <= p.energy_per_op_j)
                || (q.nmed <= p.nmed && q.energy_per_op_j < p.energy_per_op_j)
        });
        if !dominated {
            front.push(p.clone());
        }
    }
    front.sort_by(|a, b| a.nmed.partial_cmp(&b.nmed).unwrap());
    front.dedup_by(|a, b| a.label == b.label);
    front
}

/// Best (lowest-energy) design meeting an accuracy constraint.
pub fn select_under_constraint(points: &[DsePoint], nmed_budget: f64) -> Option<DsePoint> {
    points
        .iter()
        .filter(|p| p.nmed <= nmed_budget)
        .min_by(|a, b| a.energy_per_op_j.partial_cmp(&b.energy_per_op_j).unwrap())
        .cloned()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::MultFamily;

    fn pt(label: &str, nmed: f64, e: f64) -> DsePoint {
        DsePoint {
            label: label.into(),
            family: MultFamily::Exact,
            nmed,
            energy_per_op_j: e,
            logic_area_um2: 0.0,
            energy_ratio: 1.0,
        }
    }

    #[test]
    fn frontier_removes_dominated() {
        let pts = vec![
            pt("exact", 0.0, 10.0),
            pt("a", 0.01, 8.0),
            pt("dominated", 0.02, 9.0), // worse than "a" in both
            pt("b", 0.05, 4.0),
        ];
        let f = pareto_front(&pts);
        let labels: Vec<&str> = f.iter().map(|p| p.label.as_str()).collect();
        assert_eq!(labels, vec!["exact", "a", "b"]);
    }

    #[test]
    fn frontier_is_monotone() {
        let pts = vec![
            pt("x", 0.0, 10.0),
            pt("y", 0.01, 7.0),
            pt("z", 0.03, 3.0),
        ];
        let f = pareto_front(&pts);
        for w in f.windows(2) {
            assert!(w[0].nmed <= w[1].nmed);
            assert!(w[0].energy_per_op_j >= w[1].energy_per_op_j);
        }
    }

    #[test]
    fn constrained_selection() {
        let pts = vec![
            pt("exact", 0.0, 10.0),
            pt("mild", 0.001, 8.0),
            pt("aggressive", 0.1, 2.0),
        ];
        let sel = select_under_constraint(&pts, 0.01).unwrap();
        assert_eq!(sel.label, "mild");
        let sel2 = select_under_constraint(&pts, 1.0).unwrap();
        assert_eq!(sel2.label, "aggressive");
        assert!(select_under_constraint(&pts[1..], 0.0001).is_none());
    }
}
