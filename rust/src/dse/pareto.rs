//! Pareto-frontier extraction over (accuracy loss, energy) and constrained
//! selection ("best energy under an NMED budget" — the compiler's
//! accuracy-constrained selection knob, paper §III-A).

use super::sweep::DsePoint;

/// Points not dominated in (nmed, energy): a point dominates another if it
/// is no worse in both and strictly better in one. Returned sorted by
/// nmed, energy non-increasing, with coordinate duplicates removed (two
/// designs landing on the identical (nmed, energy) point keep only the
/// first in input order — one frontier entry per distinct trade-off).
/// The invariants (sorted, deduplicated, no dominated point survives,
/// every input point dominated-or-equalled by a frontier member) are
/// pinned by a seeded-random property test below.
pub fn pareto_front(points: &[DsePoint]) -> Vec<DsePoint> {
    let mut front: Vec<DsePoint> = Vec::new();
    for p in points {
        let dominated = points.iter().any(|q| {
            (q.nmed < p.nmed && q.energy_per_op_j <= p.energy_per_op_j)
                || (q.nmed <= p.nmed && q.energy_per_op_j < p.energy_per_op_j)
        });
        if !dominated {
            front.push(p.clone());
        }
    }
    front.sort_by(|a, b| a.nmed.partial_cmp(&b.nmed).unwrap());
    // Survivors sharing an nmed all carry the group's minimal energy
    // (anything else is dominated), so coordinate duplicates are adjacent
    // after the sort and consecutive dedup is complete.
    front.dedup_by(|a, b| {
        a.nmed.to_bits() == b.nmed.to_bits()
            && a.energy_per_op_j.to_bits() == b.energy_per_op_j.to_bits()
    });
    front
}

/// Best (lowest-energy) design meeting an accuracy constraint.
pub fn select_under_constraint(points: &[DsePoint], nmed_budget: f64) -> Option<DsePoint> {
    points
        .iter()
        .filter(|p| p.nmed <= nmed_budget)
        .min_by(|a, b| a.energy_per_op_j.partial_cmp(&b.energy_per_op_j).unwrap())
        .cloned()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::MultFamily;

    fn pt(label: &str, nmed: f64, e: f64) -> DsePoint {
        DsePoint {
            label: label.into(),
            family: MultFamily::Exact,
            nmed,
            energy_per_op_j: e,
            logic_area_um2: 0.0,
            energy_ratio: 1.0,
        }
    }

    #[test]
    fn frontier_removes_dominated() {
        let pts = vec![
            pt("exact", 0.0, 10.0),
            pt("a", 0.01, 8.0),
            pt("dominated", 0.02, 9.0), // worse than "a" in both
            pt("b", 0.05, 4.0),
        ];
        let f = pareto_front(&pts);
        let labels: Vec<&str> = f.iter().map(|p| p.label.as_str()).collect();
        assert_eq!(labels, vec!["exact", "a", "b"]);
    }

    #[test]
    fn frontier_is_monotone() {
        let pts = vec![
            pt("x", 0.0, 10.0),
            pt("y", 0.01, 7.0),
            pt("z", 0.03, 3.0),
        ];
        let f = pareto_front(&pts);
        for w in f.windows(2) {
            assert!(w[0].nmed <= w[1].nmed);
            assert!(w[0].energy_per_op_j >= w[1].energy_per_op_j);
        }
    }

    #[test]
    fn duplicate_coordinates_collapse_to_one_entry() {
        let pts = vec![
            pt("a", 0.01, 5.0),
            pt("twin-of-a", 0.01, 5.0),
            pt("b", 0.02, 3.0),
        ];
        let f = pareto_front(&pts);
        let labels: Vec<&str> = f.iter().map(|p| p.label.as_str()).collect();
        assert_eq!(labels, vec!["a", "b"]);
    }

    fn dominates(q: &DsePoint, p: &DsePoint) -> bool {
        (q.nmed < p.nmed && q.energy_per_op_j <= p.energy_per_op_j)
            || (q.nmed <= p.nmed && q.energy_per_op_j < p.energy_per_op_j)
    }

    #[test]
    fn frontier_properties_on_seeded_random_clouds() {
        use crate::util::proptest::{check, prop_assert};
        check(300, 0x9A9E70, |g| {
            // Quantized coordinates force plenty of ties and exact
            // duplicates — the cases a naive frontier gets wrong.
            let n = 1 + g.usize_below(40);
            let pts: Vec<DsePoint> = (0..n)
                .map(|i| {
                    let nmed = g.usize_below(8) as f64 * 0.01;
                    let energy = (1 + g.usize_below(8)) as f64 * 1e-12;
                    pt(&format!("p{i}"), nmed, energy)
                })
                .collect();
            let f = pareto_front(&pts);
            prop_assert(!f.is_empty(), "frontier of a non-empty cloud is non-empty")?;
            for w in f.windows(2) {
                prop_assert(w[0].nmed <= w[1].nmed, "sorted by nmed")?;
                prop_assert(
                    w[0].energy_per_op_j >= w[1].energy_per_op_j,
                    "energy non-increasing along the frontier",
                )?;
                prop_assert(
                    !(w[0].nmed == w[1].nmed
                        && w[0].energy_per_op_j == w[1].energy_per_op_j),
                    "frontier is deduplicated",
                )?;
            }
            for p in &f {
                prop_assert(
                    pts.iter().any(|q| q.label == p.label),
                    "frontier points come from the input",
                )?;
                prop_assert(
                    !pts.iter().any(|q| dominates(q, p)),
                    "no dominated point survives",
                )?;
            }
            for p in &pts {
                prop_assert(
                    f.iter().any(|q| {
                        dominates(q, p)
                            || (q.nmed == p.nmed && q.energy_per_op_j == p.energy_per_op_j)
                    }),
                    "every input point is dominated or equalled by the frontier",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn constrained_selection() {
        let pts = vec![
            pt("exact", 0.0, 10.0),
            pt("mild", 0.001, 8.0),
            pt("aggressive", 0.1, 2.0),
        ];
        let sel = select_under_constraint(&pts, 0.01).unwrap();
        assert_eq!(sel.label, "mild");
        let sel2 = select_under_constraint(&pts, 1.0).unwrap();
        assert_eq!(sel2.label, "aggressive");
        assert!(select_under_constraint(&pts[1..], 0.0001).is_none());
    }
}
