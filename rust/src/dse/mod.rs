//! Design-space exploration engine (paper §VI's stated future work,
//! implemented here as a first-class feature): jointly sweep multiplier
//! family × compressor type × approximate-column budget, score each point
//! by (accuracy, energy, area), and extract the Pareto frontier under an
//! application accuracy constraint.

pub mod sweep;
pub mod pareto;
pub mod cli;

pub use pareto::pareto_front;
pub use sweep::{sweep_configs, sweep_configs_cached, DsePoint};
