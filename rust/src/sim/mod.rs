//! Gate-level simulation for functional verification and switching-activity
//! extraction (the power model's input).
//!
//! Two engines behind one [`Simulator`] trait, cross-checked bit-for-bit
//! against each other in tests (`rust/tests/sim_equivalence.rs`):
//!
//! * [`event::EventSim`] — the scalar reference: a classic event-driven
//!   two-value simulator. Only gates whose inputs changed are re-evaluated,
//!   so it wins on *narrow-cone* streams (weight-stationary PE traffic where
//!   few input bits move per cycle) and it is the engine the PE-level
//!   workloads use.
//! * [`bitparallel::BitParallelSim`] — the throughput engine: every net is a
//!   plane *group* of `u64` words (lane `w·64 + l` = input vector
//!   `t + w·64 + l`), so one topological sweep evaluates `64 × words`
//!   vectors with pure bitwise ops and toggles are counted with
//!   XOR/popcount. The group width follows the host's SIMD tier through
//!   [`crate::util::simd`] (4 words under AVX2, 2 under NEON, 1 scalar —
//!   see `DESIGN.md` §"SIMD kernels"); every width is bit-identical to
//!   the one-word sweep. This is the hot path for exhaustive error
//!   characterization, activity-based power (Table II) and the DSE sweep —
//!   50×+ faster than the scalar engine on random/exhaustive workloads
//!   (measured in `benches/hotpaths.rs`).
//!
//! [`activity`] layers workload helpers and a multi-threaded activity
//! extractor on top of the bit-parallel engine.

pub mod event;
pub mod bitparallel;
pub mod activity;

pub use activity::{activity_bitparallel, activity_parallel, ActivityReport};
pub use bitparallel::BitParallelSim;
pub use event::EventSim;

/// Common interface over the gate-simulation engines.
///
/// Both engines are *stateful* stream simulators: toggle counts accumulate
/// across [`Simulator::run`] calls, the first vector ever applied
/// establishes net state without counting toggles, and every later
/// consecutive-vector transition adds `value_changed(net)` to that net's
/// count — so a stream split across calls gives bit-identical results to
/// one call with the concatenated stream.
pub trait Simulator {
    /// Engine name for reports and benches.
    fn name(&self) -> &'static str;

    /// Apply a stream of input vectors (one `bool` per primary input, in
    /// declaration order) and return the primary-output bits per vector
    /// (declaration order).
    fn run(&mut self, vectors: &[Vec<bool>]) -> Vec<Vec<bool>>;

    /// Per-net cumulative toggle counts (indexed by `NetId`).
    fn toggles(&self) -> &[u64];

    /// Number of vectors applied so far.
    fn vectors(&self) -> u64;

    /// Total toggles across all nets.
    fn total_toggles(&self) -> u64 {
        self.toggles().iter().sum()
    }
}
