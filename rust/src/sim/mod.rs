//! Gate-level simulation for functional verification and switching-activity
//! extraction (the power model's input).
//!
//! Two engines, cross-checked against each other in tests:
//!
//! * [`event::EventSim`] — a classic event-driven two-value simulator:
//!   only gates whose inputs changed are re-evaluated, toggle counts are
//!   accumulated per net. This is the engine the PE-level workloads use.
//! * [`activity::activity_bitparallel`] — a 64-way bit-parallel sweep:
//!   64 consecutive input vectors are evaluated per pass and toggles are
//!   counted with XOR/popcount. This is the hot path for Table II's
//!   fixed multiplication workloads (see benches/hotpaths.rs).

pub mod event;
pub mod activity;

pub use activity::{activity_bitparallel, ActivityReport};
pub use event::EventSim;
