//! N×64-way bit-parallel gate simulation.
//!
//! Every net carries a *plane-group* of `u64` bit-planes: lane `l` of word
//! `w` is the net's boolean value under input vector `t + w·64 + l`. One
//! topological sweep over the netlist therefore evaluates `words × 64`
//! input vectors with pure bitwise ops (AND/OR/XOR/NOT and the mux as
//! AND-OR) — 50×+ faster than scalar event-driven simulation on the
//! random/exhaustive workloads where most of the cone toggles every cycle
//! (see `benches/hotpaths.rs`). The group width follows the host's SIMD
//! tier via [`crate::util::simd`] (4 words per 256-bit AVX2 op, 2 per
//! NEON op, 1 scalar), and every width is bit-identical to the
//! one-word-at-a-time scalar sweep — see DESIGN.md §"SIMD kernels".
//!
//! Toggle semantics are bit-identical to [`super::event::EventSim`]:
//! applying the very first vector establishes state without counting, and
//! every later consecutive-vector transition contributes
//! `popcount(prev ^ next)` per net. Within a word that is
//! `popcount((x ^ (x >> 1)) & intra_mask)`; across word, batch and
//! [`Simulator::run`]-call boundaries the last *live* lane of the previous
//! word is compared against lane 0 of the next, and dead lanes of a final
//! partial word are masked out of every popcount.
//!
//! Entry points:
//!
//! * the [`Simulator`] trait (`bool`-vector streams) — convenient, shared
//!   with the scalar engine, used by the cross-engine equivalence tests;
//! * [`BitParallelSim::run_packed`] / [`BitParallelSim::run_packed_wide`]
//!   — the zero-copy fast paths for callers that produce lane-packed
//!   input planes directly ([`counting_planes`] /
//!   [`counting_planes_wide`] build the planes of consecutive operand
//!   values in O(bits·words), which is how exhaustive characterization
//!   feeds the evaluator without materializing any per-vector data; see
//!   `mult::error_metrics::exhaustive_netlist`).

use super::Simulator;
use crate::gates::Netlist;

/// Stateful 64-lane bit-parallel simulator for one netlist.
pub struct BitParallelSim<'a> {
    nl: &'a Netlist,
    /// Per-net cumulative toggle counts.
    toggles: Vec<u64>,
    /// Value of every net under the last applied vector (batch boundary).
    prev_last: Option<Vec<bool>>,
    /// Number of vectors applied.
    vectors: u64,
    /// Scratch: lane-packed input assignment (one word per primary input).
    assign: Vec<u64>,
    /// Scratch: lane-packed value per net.
    vals: Vec<u64>,
}

impl<'a> BitParallelSim<'a> {
    pub fn new(nl: &'a Netlist) -> Self {
        Self {
            nl,
            toggles: vec![0; nl.gates().len()],
            prev_last: None,
            vectors: 0,
            assign: vec![0; nl.inputs().len()],
            vals: Vec::new(),
        }
    }

    /// Fast path: apply `lanes` (1..=64) vectors already packed as one
    /// bit-plane word per primary input (declaration order; lane `l` =
    /// vector `l` of the batch, lanes beyond `lanes` are ignored). Toggle
    /// accounting is identical to the trait path. Returns the packed value
    /// of every net (indexable by `NetId`), valid until the next call.
    /// The one-word case of [`BitParallelSim::run_packed_wide`].
    pub fn run_packed(&mut self, assignment: &[u64], lanes: usize) -> &[u64] {
        assert!(0 < lanes && lanes <= 64, "1..=64 lanes per sweep");
        self.run_packed_wide(assignment, 1, lanes)
    }

    /// Wide fast path: apply `lanes` vectors packed as a plane-group of
    /// `words` `u64` words per primary input (input-major — input `i`'s
    /// words at `assignment[i·words .. (i+1)·words]`; word `w` lane `l` =
    /// vector `w·64 + l` of the batch). `lanes` must fill every word but
    /// the last, i.e. `words == lanes.div_ceil(64)`.
    ///
    /// Toggle accounting is bit-identical to streaming the same vectors
    /// through [`BitParallelSim::run_packed`] 64 at a time: intra-word
    /// transitions come from masked `popcount(x ^ (x >> 1))`, word-to-word
    /// (and batch-to-batch) boundaries compare the previous word's last
    /// live lane against lane 0 of the next, and dead bits of a final
    /// partial word are masked out of every count and out of the carried
    /// boundary state. Returns the packed value of every net, net-major
    /// (`words` words per net: `vals[net.idx()·words + w]`), valid until
    /// the next call.
    pub fn run_packed_wide(&mut self, assignment: &[u64], words: usize, lanes: usize) -> &[u64] {
        assert!(words >= 1, "at least one plane word");
        assert!(
            lanes > (words - 1) * 64 && lanes <= words * 64,
            "lanes must fill all words but the last (words = lanes.div_ceil(64))"
        );
        let mut vals = std::mem::take(&mut self.vals);
        self.nl.eval_wide_into(assignment, words, &mut vals);

        let last_bits = lanes - (words - 1) * 64; // 1..=64
        let last_mask = if last_bits == 64 {
            u64::MAX
        } else {
            (1u64 << last_bits) - 1
        };
        let first = self.prev_last.is_none();
        let mut prev = self
            .prev_last
            .take()
            .unwrap_or_else(|| vec![false; self.nl.gates().len()]);
        for (net, group) in vals.chunks_exact(words).enumerate() {
            let mut toggles = 0u64;
            let mut carry = prev[net];
            for (w, &raw) in group.iter().enumerate() {
                let (mask, bits) = if w + 1 == words {
                    (last_mask, last_bits)
                } else {
                    (u64::MAX, 64usize)
                };
                let x = raw & mask;
                // Lane l vs l+1 transitions within this word (live lanes).
                toggles += ((x ^ (x >> 1)) & (mask >> 1)).count_ones() as u64;
                // Boundary: last live lane of the previous word (or of the
                // previous batch — skipped for the very first vector ever)
                // vs lane 0 of this word.
                if (w > 0 || !first) && ((x & 1 != 0) != carry) {
                    toggles += 1;
                }
                carry = (x >> (bits - 1)) & 1 != 0;
            }
            self.toggles[net] += toggles;
            prev[net] = carry;
        }
        self.prev_last = Some(prev);
        self.vectors += lanes as u64;
        self.vals = vals;
        &self.vals
    }

    /// Pack a batch of `bool`-vectors into lane plane-groups and sweep them
    /// all in one topological pass (any batch size ≥ 1; the group width is
    /// `batch.len().div_ceil(64)` words), discarding outputs. Toggle
    /// accounting still applies — this is the path for callers that only
    /// read toggle counts (activity extraction).
    pub fn run_bools(&mut self, batch: &[Vec<bool>]) {
        let lanes = batch.len();
        assert!(lanes > 0, "empty batch");
        let words = lanes.div_ceil(64);
        let n_inputs = self.nl.inputs().len();
        let mut assign = std::mem::take(&mut self.assign);
        assign.clear();
        assign.resize(n_inputs * words, 0u64);
        for (l, vec) in batch.iter().enumerate() {
            assert_eq!(vec.len(), n_inputs, "vector arity");
            let (w, bit) = (l / 64, l % 64);
            for (i, &b) in vec.iter().enumerate() {
                if b {
                    assign[i * words + w] |= 1u64 << bit;
                }
            }
        }
        self.run_packed_wide(&assign, words, lanes);
        self.assign = assign;
    }

    /// Apply up to 64 `bool`-vectors in one sweep; returns per-vector
    /// output bits.
    fn run_batch(&mut self, batch: &[Vec<bool>]) -> Vec<Vec<bool>> {
        self.run_bools(batch);
        let lanes = batch.len();
        let outs = self.nl.outputs();
        let vals = &self.vals;
        (0..lanes)
            .map(|l| {
                outs.iter()
                    .map(|(_, id)| (vals[id.idx()] >> l) & 1 != 0)
                    .collect()
            })
            .collect()
    }

    pub fn toggles(&self) -> &[u64] {
        &self.toggles
    }

    pub fn total_toggles(&self) -> u64 {
        self.toggles.iter().sum()
    }

    pub fn vectors(&self) -> u64 {
        self.vectors
    }
}

impl Simulator for BitParallelSim<'_> {
    fn name(&self) -> &'static str {
        "bit-parallel"
    }

    fn run(&mut self, vectors: &[Vec<bool>]) -> Vec<Vec<bool>> {
        let mut out = Vec::with_capacity(vectors.len());
        for batch in vectors.chunks(64) {
            out.extend(self.run_batch(batch));
        }
        out
    }

    fn toggles(&self) -> &[u64] {
        &self.toggles
    }

    fn vectors(&self) -> u64 {
        self.vectors
    }
}

/// Bit-planes of 64 consecutive operand values: plane `i` holds bit `i` of
/// `start + l` in lane `l`. Lanes of an exhaustive sweep count through the
/// operand space, so the low six planes are fixed lane patterns and the
/// rest broadcast `start`'s bits — no per-vector work at all.
/// `start` must be 64-aligned (0 qualifies, covering sub-64-lane sweeps).
pub fn counting_planes(start: u64, bits: usize) -> Vec<u64> {
    assert_eq!(start % 64, 0, "counting block must be 64-aligned");
    const LANE_BIT: [u64; 6] = [
        0xAAAA_AAAA_AAAA_AAAA,
        0xCCCC_CCCC_CCCC_CCCC,
        0xF0F0_F0F0_F0F0_F0F0,
        0xFF00_FF00_FF00_FF00,
        0xFFFF_0000_FFFF_0000,
        0xFFFF_FFFF_0000_0000,
    ];
    (0..bits)
        .map(|i| {
            if i < 6 {
                LANE_BIT[i]
            } else if (start >> i) & 1 == 1 {
                u64::MAX
            } else {
                0
            }
        })
        .collect()
}

/// Plane-group variant of [`counting_planes`]: bit `i` of the values
/// `start + w·64 + l` lands in word `w`, lane `l`, laid out input-major at
/// `[i·words + w]` — directly consumable by
/// [`crate::gates::Netlist::eval_wide_into`] /
/// [`BitParallelSim::run_packed_wide`] as the planes of `words × 64`
/// consecutive operand values.
pub fn counting_planes_wide(start: u64, bits: usize, words: usize) -> Vec<u64> {
    assert!(words >= 1, "at least one plane word");
    let mut out = vec![0u64; bits * words];
    for w in 0..words {
        let planes = counting_planes(start + 64 * w as u64, bits);
        for (i, &p) in planes.iter().enumerate() {
            out[i * words + w] = p;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::event::EventSim;
    use crate::sim::Simulator;
    use crate::util::rng::Pcg32;

    fn random_vectors(n_inputs: usize, n: usize, seed: u64) -> Vec<Vec<bool>> {
        let mut rng = Pcg32::new(seed);
        (0..n)
            .map(|_| (0..n_inputs).map(|_| rng.next_u32() & 1 != 0).collect())
            .collect()
    }

    #[test]
    fn outputs_match_event_sim_on_random_stream() {
        let nl = crate::mult::pptree::build_exact(6);
        let vectors = random_vectors(nl.inputs().len(), 200, 0xB17);
        let mut bp = BitParallelSim::new(&nl);
        let mut ev = EventSim::new(&nl);
        let bp_out = Simulator::run(&mut bp, &vectors);
        let ev_out = Simulator::run(&mut ev, &vectors);
        assert_eq!(bp_out, ev_out);
        assert_eq!(bp.toggles(), ev.toggles());
        assert_eq!(BitParallelSim::vectors(&bp), 200);
    }

    #[test]
    fn state_carries_across_run_calls() {
        // Many small run() calls must equal one big call (boundary stitching).
        let nl = crate::mult::pptree::build_exact(4);
        let vectors = random_vectors(nl.inputs().len(), 130, 7);
        let mut whole = BitParallelSim::new(&nl);
        Simulator::run(&mut whole, &vectors);
        let mut pieces = BitParallelSim::new(&nl);
        for chunk in vectors.chunks(17) {
            Simulator::run(&mut pieces, chunk);
        }
        assert_eq!(whole.toggles(), pieces.toggles());
    }

    #[test]
    fn first_vector_counts_no_toggles() {
        let nl = crate::mult::pptree::build_exact(4);
        let mut bp = BitParallelSim::new(&nl);
        let v: Vec<bool> = vec![true; nl.inputs().len()];
        Simulator::run(&mut bp, std::slice::from_ref(&v));
        assert_eq!(bp.total_toggles(), 0);
        // Re-applying the identical vector still toggles nothing.
        Simulator::run(&mut bp, std::slice::from_ref(&v));
        assert_eq!(bp.total_toggles(), 0);
    }

    #[test]
    fn empty_run_is_a_no_op() {
        let nl = crate::mult::pptree::build_exact(4);
        let mut bp = BitParallelSim::new(&nl);
        let out = Simulator::run(&mut bp, &[]);
        assert!(out.is_empty());
        assert_eq!(BitParallelSim::vectors(&bp), 0);
    }

    #[test]
    fn counting_planes_enumerate_consecutive_values() {
        for start in [0u64, 64, 192] {
            let planes = counting_planes(start, 9);
            for lane in 0..64u64 {
                let v = planes.iter().enumerate().fold(0u64, |acc, (i, &w)| {
                    acc | (((w >> lane) & 1) << i)
                });
                assert_eq!(v, (start + lane) & 0x1FF, "start={start} lane={lane}");
            }
        }
    }

    #[test]
    fn packed_path_matches_trait_path() {
        // Same 128 consecutive-b vectors through run_packed and run().
        let nl = crate::mult::pptree::build_exact(6);
        let a = 0b101101u64;
        let vectors: Vec<Vec<bool>> = (0..128u64)
            .map(|b| {
                let mut v = Vec::with_capacity(12);
                for i in 0..6 {
                    v.push((a >> i) & 1 != 0);
                }
                for i in 0..6 {
                    v.push((b % 64 >> i) & 1 != 0);
                }
                v
            })
            .collect();
        let mut via_trait = BitParallelSim::new(&nl);
        let trait_out = Simulator::run(&mut via_trait, &vectors);

        let mut packed = BitParallelSim::new(&nl);
        let mut assignment = Vec::new();
        for i in 0..6 {
            assignment.push(if (a >> i) & 1 == 1 { u64::MAX } else { 0 });
        }
        assignment.extend(counting_planes(0, 6));
        let out_ids: Vec<usize> = nl.outputs().iter().map(|(_, id)| id.idx()).collect();
        let mut packed_out = Vec::new();
        for _block in 0..2 {
            let vals = packed.run_packed(&assignment, 64);
            for lane in 0..64 {
                packed_out.push(
                    out_ids
                        .iter()
                        .map(|&idx| (vals[idx] >> lane) & 1 != 0)
                        .collect::<Vec<bool>>(),
                );
            }
        }
        assert_eq!(trait_out, packed_out);
        assert_eq!(via_trait.toggles(), packed.toggles());
    }

    #[test]
    fn wide_sweeps_match_narrow_sweeps_bit_for_bit() {
        // One run_packed_wide sweep of W words must equal W sequential
        // run_packed sweeps of the same vectors: outputs, toggles, vectors.
        let nl = crate::mult::pptree::build_exact(6);
        let a_planes: Vec<u64> = (0..6)
            .map(|i| if (0b110101u64 >> i) & 1 == 1 { u64::MAX } else { 0 })
            .collect();
        for words in [2usize, 3, 4] {
            let mut wide = BitParallelSim::new(&nl);
            let mut narrow = BitParallelSim::new(&nl);
            for block in 0..2u64 {
                let start = block * 64 * words as u64;
                let mut assignment = Vec::with_capacity(12 * words);
                for &ap in &a_planes {
                    for _ in 0..words {
                        assignment.push(ap);
                    }
                }
                assignment.extend(counting_planes_wide(start, 6, words));
                let vals = wide.run_packed_wide(&assignment, words, words * 64).to_vec();
                for w in 0..words {
                    let mut narrow_assign: Vec<u64> = a_planes.clone();
                    narrow_assign.extend(counting_planes(start + 64 * w as u64, 6));
                    let nv = narrow.run_packed(&narrow_assign, 64);
                    for (net, &x) in nv.iter().enumerate() {
                        assert_eq!(vals[net * words + w], x, "words={words} w={w} net={net}");
                    }
                }
            }
            assert_eq!(wide.toggles(), narrow.toggles(), "words={words}");
            assert_eq!(wide.vectors(), narrow.vectors());
        }
    }

    #[test]
    fn partial_final_word_masks_dead_lanes() {
        // Vector counts straddling the word boundary: wide run_bools (one
        // sweep) must match the event-driven engine exactly — the dead
        // lanes of the final partial word must never contribute toggles.
        let nl = crate::mult::pptree::build_exact(5);
        for &count in &[1usize, 63, 64, 65, 127, 130, 200] {
            let vectors = random_vectors(nl.inputs().len(), count, 0xD0 + count as u64);
            let mut wide = BitParallelSim::new(&nl);
            wide.run_bools(&vectors); // single sweep, words = ceil(count/64)
            let mut ev = EventSim::new(&nl);
            Simulator::run(&mut ev, &vectors);
            assert_eq!(wide.toggles(), ev.toggles(), "count={count}");
            assert_eq!(wide.vectors(), count as u64);
        }
    }

    #[test]
    fn counting_planes_wide_layout_matches_narrow_planes() {
        let wide = counting_planes_wide(128, 9, 3);
        assert_eq!(wide.len(), 27);
        for w in 0..3 {
            let narrow = counting_planes(128 + 64 * w as u64, 9);
            for (i, &p) in narrow.iter().enumerate() {
                assert_eq!(wide[i * 3 + w], p, "w={w} bit={i}");
            }
        }
    }
}
