//! 64-way bit-parallel gate simulation.
//!
//! Every net carries a `u64` *bit-plane*: lane `l` of the word is the net's
//! boolean value under input vector `t + l`. One topological sweep over the
//! netlist therefore evaluates 64 input vectors with pure bitwise ops
//! (AND/OR/XOR/NOT and the mux as AND-OR), i.e. the per-vector cost is
//! `gates / 64` word operations — 50×+ faster than scalar event-driven
//! simulation on the random/exhaustive workloads where most of the cone
//! toggles every cycle (see `benches/hotpaths.rs`).
//!
//! Toggle semantics are bit-identical to [`super::event::EventSim`]:
//! applying the very first vector establishes state without counting, and
//! every later consecutive-vector transition contributes
//! `popcount(prev ^ next)` per net. Within a batch that is
//! `popcount((x ^ (x >> 1)) & intra_mask)`; across batch (and across
//! [`Simulator::run`] call) boundaries the last lane of the previous word
//! is compared against lane 0 of the next.
//!
//! Two entry points:
//!
//! * the [`Simulator`] trait (`bool`-vector streams) — convenient, shared
//!   with the scalar engine, used by the cross-engine equivalence tests;
//! * [`BitParallelSim::run_packed`] — the zero-copy fast path for callers
//!   that produce lane-packed input planes directly ([`counting_planes`]
//!   builds the planes of 64 consecutive operand values in O(bits), which
//!   is how exhaustive characterization feeds the evaluator without
//!   materializing any per-vector data; see
//!   `mult::error_metrics::exhaustive_netlist`).

use super::Simulator;
use crate::gates::Netlist;

/// Stateful 64-lane bit-parallel simulator for one netlist.
pub struct BitParallelSim<'a> {
    nl: &'a Netlist,
    /// Per-net cumulative toggle counts.
    toggles: Vec<u64>,
    /// Value of every net under the last applied vector (batch boundary).
    prev_last: Option<Vec<bool>>,
    /// Number of vectors applied.
    vectors: u64,
    /// Scratch: lane-packed input assignment (one word per primary input).
    assign: Vec<u64>,
    /// Scratch: lane-packed value per net.
    vals: Vec<u64>,
}

impl<'a> BitParallelSim<'a> {
    pub fn new(nl: &'a Netlist) -> Self {
        Self {
            nl,
            toggles: vec![0; nl.gates().len()],
            prev_last: None,
            vectors: 0,
            assign: vec![0; nl.inputs().len()],
            vals: Vec::new(),
        }
    }

    /// Fast path: apply `lanes` vectors already packed as one bit-plane
    /// word per primary input (declaration order; lane `l` = vector `l` of
    /// the batch, lanes beyond `lanes` are ignored). Toggle accounting is
    /// identical to the trait path. Returns the packed value of every net
    /// (indexable by `NetId`), valid until the next call.
    pub fn run_packed(&mut self, assignment: &[u64], lanes: usize) -> &[u64] {
        assert!(0 < lanes && lanes <= 64, "1..=64 lanes per sweep");
        let mut vals = std::mem::take(&mut self.vals);
        self.nl.eval_u64_into(assignment, &mut vals);

        let mask = if lanes == 64 {
            u64::MAX
        } else {
            (1u64 << lanes) - 1
        };
        // Lane l vs lane l+1 transitions live in bits 0..lanes-1 of x^(x>>1).
        let intra_mask = mask >> 1;
        match &mut self.prev_last {
            Some(prev) => {
                for (net, &x) in vals.iter().enumerate() {
                    let x = x & mask;
                    self.toggles[net] += ((x ^ (x >> 1)) & intra_mask).count_ones() as u64;
                    // Boundary: previous batch's last vector vs lane 0.
                    if (x & 1 != 0) != prev[net] {
                        self.toggles[net] += 1;
                    }
                    prev[net] = (x >> (lanes - 1)) & 1 != 0;
                }
            }
            None => {
                let mut prev = Vec::with_capacity(vals.len());
                for (net, &x) in vals.iter().enumerate() {
                    let x = x & mask;
                    self.toggles[net] += ((x ^ (x >> 1)) & intra_mask).count_ones() as u64;
                    prev.push((x >> (lanes - 1)) & 1 != 0);
                }
                self.prev_last = Some(prev);
            }
        }
        self.vectors += lanes as u64;
        self.vals = vals;
        &self.vals
    }

    /// Pack up to 64 `bool`-vectors into lane planes and sweep them,
    /// discarding outputs. Toggle accounting still applies — this is the
    /// path for callers that only read toggle counts (activity extraction).
    pub fn run_bools(&mut self, batch: &[Vec<bool>]) {
        let lanes = batch.len();
        let n_inputs = self.nl.inputs().len();
        let mut assign = std::mem::take(&mut self.assign);
        for w in assign.iter_mut() {
            *w = 0;
        }
        for (l, vec) in batch.iter().enumerate() {
            assert_eq!(vec.len(), n_inputs, "vector arity");
            for (i, &bit) in vec.iter().enumerate() {
                if bit {
                    assign[i] |= 1u64 << l;
                }
            }
        }
        self.run_packed(&assign, lanes);
        self.assign = assign;
    }

    /// Apply up to 64 `bool`-vectors in one sweep; returns per-vector
    /// output bits.
    fn run_batch(&mut self, batch: &[Vec<bool>]) -> Vec<Vec<bool>> {
        self.run_bools(batch);
        let lanes = batch.len();
        let outs = self.nl.outputs();
        let vals = &self.vals;
        (0..lanes)
            .map(|l| {
                outs.iter()
                    .map(|(_, id)| (vals[id.idx()] >> l) & 1 != 0)
                    .collect()
            })
            .collect()
    }

    pub fn toggles(&self) -> &[u64] {
        &self.toggles
    }

    pub fn total_toggles(&self) -> u64 {
        self.toggles.iter().sum()
    }

    pub fn vectors(&self) -> u64 {
        self.vectors
    }
}

impl Simulator for BitParallelSim<'_> {
    fn name(&self) -> &'static str {
        "bit-parallel"
    }

    fn run(&mut self, vectors: &[Vec<bool>]) -> Vec<Vec<bool>> {
        let mut out = Vec::with_capacity(vectors.len());
        for batch in vectors.chunks(64) {
            out.extend(self.run_batch(batch));
        }
        out
    }

    fn toggles(&self) -> &[u64] {
        &self.toggles
    }

    fn vectors(&self) -> u64 {
        self.vectors
    }
}

/// Bit-planes of 64 consecutive operand values: plane `i` holds bit `i` of
/// `start + l` in lane `l`. Lanes of an exhaustive sweep count through the
/// operand space, so the low six planes are fixed lane patterns and the
/// rest broadcast `start`'s bits — no per-vector work at all.
/// `start` must be 64-aligned (0 qualifies, covering sub-64-lane sweeps).
pub fn counting_planes(start: u64, bits: usize) -> Vec<u64> {
    assert_eq!(start % 64, 0, "counting block must be 64-aligned");
    const LANE_BIT: [u64; 6] = [
        0xAAAA_AAAA_AAAA_AAAA,
        0xCCCC_CCCC_CCCC_CCCC,
        0xF0F0_F0F0_F0F0_F0F0,
        0xFF00_FF00_FF00_FF00,
        0xFFFF_0000_FFFF_0000,
        0xFFFF_FFFF_0000_0000,
    ];
    (0..bits)
        .map(|i| {
            if i < 6 {
                LANE_BIT[i]
            } else if (start >> i) & 1 == 1 {
                u64::MAX
            } else {
                0
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::event::EventSim;
    use crate::sim::Simulator;
    use crate::util::rng::Pcg32;

    fn random_vectors(n_inputs: usize, n: usize, seed: u64) -> Vec<Vec<bool>> {
        let mut rng = Pcg32::new(seed);
        (0..n)
            .map(|_| (0..n_inputs).map(|_| rng.next_u32() & 1 != 0).collect())
            .collect()
    }

    #[test]
    fn outputs_match_event_sim_on_random_stream() {
        let nl = crate::mult::pptree::build_exact(6);
        let vectors = random_vectors(nl.inputs().len(), 200, 0xB17);
        let mut bp = BitParallelSim::new(&nl);
        let mut ev = EventSim::new(&nl);
        let bp_out = Simulator::run(&mut bp, &vectors);
        let ev_out = Simulator::run(&mut ev, &vectors);
        assert_eq!(bp_out, ev_out);
        assert_eq!(bp.toggles(), ev.toggles());
        assert_eq!(BitParallelSim::vectors(&bp), 200);
    }

    #[test]
    fn state_carries_across_run_calls() {
        // Many small run() calls must equal one big call (boundary stitching).
        let nl = crate::mult::pptree::build_exact(4);
        let vectors = random_vectors(nl.inputs().len(), 130, 7);
        let mut whole = BitParallelSim::new(&nl);
        Simulator::run(&mut whole, &vectors);
        let mut pieces = BitParallelSim::new(&nl);
        for chunk in vectors.chunks(17) {
            Simulator::run(&mut pieces, chunk);
        }
        assert_eq!(whole.toggles(), pieces.toggles());
    }

    #[test]
    fn first_vector_counts_no_toggles() {
        let nl = crate::mult::pptree::build_exact(4);
        let mut bp = BitParallelSim::new(&nl);
        let v: Vec<bool> = vec![true; nl.inputs().len()];
        Simulator::run(&mut bp, std::slice::from_ref(&v));
        assert_eq!(bp.total_toggles(), 0);
        // Re-applying the identical vector still toggles nothing.
        Simulator::run(&mut bp, std::slice::from_ref(&v));
        assert_eq!(bp.total_toggles(), 0);
    }

    #[test]
    fn empty_run_is_a_no_op() {
        let nl = crate::mult::pptree::build_exact(4);
        let mut bp = BitParallelSim::new(&nl);
        let out = Simulator::run(&mut bp, &[]);
        assert!(out.is_empty());
        assert_eq!(BitParallelSim::vectors(&bp), 0);
    }

    #[test]
    fn counting_planes_enumerate_consecutive_values() {
        for start in [0u64, 64, 192] {
            let planes = counting_planes(start, 9);
            for lane in 0..64u64 {
                let v = planes.iter().enumerate().fold(0u64, |acc, (i, &w)| {
                    acc | (((w >> lane) & 1) << i)
                });
                assert_eq!(v, (start + lane) & 0x1FF, "start={start} lane={lane}");
            }
        }
    }

    #[test]
    fn packed_path_matches_trait_path() {
        // Same 128 consecutive-b vectors through run_packed and run().
        let nl = crate::mult::pptree::build_exact(6);
        let a = 0b101101u64;
        let vectors: Vec<Vec<bool>> = (0..128u64)
            .map(|b| {
                let mut v = Vec::with_capacity(12);
                for i in 0..6 {
                    v.push((a >> i) & 1 != 0);
                }
                for i in 0..6 {
                    v.push((b % 64 >> i) & 1 != 0);
                }
                v
            })
            .collect();
        let mut via_trait = BitParallelSim::new(&nl);
        let trait_out = Simulator::run(&mut via_trait, &vectors);

        let mut packed = BitParallelSim::new(&nl);
        let mut assignment = Vec::new();
        for i in 0..6 {
            assignment.push(if (a >> i) & 1 == 1 { u64::MAX } else { 0 });
        }
        assignment.extend(counting_planes(0, 6));
        let out_ids: Vec<usize> = nl.outputs().iter().map(|(_, id)| id.idx()).collect();
        let mut packed_out = Vec::new();
        for _block in 0..2 {
            let vals = packed.run_packed(&assignment, 64);
            for lane in 0..64 {
                packed_out.push(
                    out_ids
                        .iter()
                        .map(|&idx| (vals[idx] >> lane) & 1 != 0)
                        .collect::<Vec<bool>>(),
                );
            }
        }
        assert_eq!(trait_out, packed_out);
        assert_eq!(via_trait.toggles(), packed.toggles());
    }
}
