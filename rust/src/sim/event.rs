//! Event-driven two-value gate simulator with per-net toggle counting.
//!
//! The netlist's creation order is topological, so a single forward sweep
//! over "dirty" gates settles combinational logic in one pass: we keep a
//! dirty flag per gate and process gates in index order, marking fanout
//! gates dirty when an output changes. Complexity per vector is
//! O(changed cone) rather than O(netlist).

use super::Simulator;
use crate::gates::{GateKind, Netlist};

/// Incremental simulator state for one netlist.
pub struct EventSim<'a> {
    nl: &'a Netlist,
    /// Current boolean value per net.
    values: Vec<bool>,
    /// Per-net cumulative toggle counts.
    toggles: Vec<u64>,
    /// Fanout adjacency: net → gates reading it.
    fanout: Vec<Vec<u32>>,
    /// Scratch dirty flags.
    dirty: Vec<bool>,
    /// Number of vectors applied.
    vectors: u64,
    /// Cumulative count of gate evaluations (the "events" measure).
    pub events: u64,
    /// Input gate index per primary-input ordinal.
    input_gates: Vec<u32>,
    initialized: bool,
    /// Min-ordered worklist of dirty gates (topological settle order).
    heap: std::collections::BinaryHeap<std::cmp::Reverse<u32>>,
}

impl<'a> EventSim<'a> {
    pub fn new(nl: &'a Netlist) -> Self {
        let n = nl.gates().len();
        let mut fanout: Vec<Vec<u32>> = vec![Vec::new(); n];
        for (gi, g) in nl.gates().iter().enumerate() {
            for k in 0..g.kind.arity() {
                fanout[g.inputs[k].idx()].push(gi as u32);
            }
        }
        let input_gates = nl.inputs().iter().map(|(_, id)| id.0).collect();
        Self {
            nl,
            values: vec![false; n],
            toggles: vec![0; n],
            fanout,
            dirty: vec![false; n],
            vectors: 0,
            events: 0,
            input_gates,
            initialized: false,
            heap: std::collections::BinaryHeap::new(),
        }
    }

    fn eval_gate(&self, gi: usize) -> bool {
        let g = &self.nl.gates()[gi];
        let v = |id: crate::gates::NetId| self.values[id.idx()];
        match g.kind {
            GateKind::Const0 => false,
            GateKind::Const1 => true,
            GateKind::Input => self.values[gi], // set externally
            GateKind::Buf => v(g.inputs[0]),
            GateKind::Not => !v(g.inputs[0]),
            GateKind::And2 => v(g.inputs[0]) & v(g.inputs[1]),
            GateKind::Or2 => v(g.inputs[0]) | v(g.inputs[1]),
            GateKind::Xor2 => v(g.inputs[0]) ^ v(g.inputs[1]),
            GateKind::Nand2 => !(v(g.inputs[0]) & v(g.inputs[1])),
            GateKind::Nor2 => !(v(g.inputs[0]) | v(g.inputs[1])),
            GateKind::Xnor2 => !(v(g.inputs[0]) ^ v(g.inputs[1])),
            GateKind::Mux2 => {
                if v(g.inputs[2]) {
                    v(g.inputs[1])
                } else {
                    v(g.inputs[0])
                }
            }
        }
    }

    /// Apply one input vector (primary-input order) and settle.
    /// Returns the primary-output values.
    pub fn step(&mut self, inputs: &[bool]) -> Vec<bool> {
        assert_eq!(inputs.len(), self.input_gates.len());
        let first = !self.initialized;
        if first {
            // Initialize: evaluate everything once (constants included).
            for gi in 0..self.nl.gates().len() {
                self.dirty[gi] = true;
            }
            self.initialized = true;
        }
        let mut changed_inputs = 0usize;
        for (ord, &gi) in self.input_gates.iter().enumerate() {
            let gi = gi as usize;
            if self.values[gi] != inputs[ord] {
                changed_inputs += 1;
                self.values[gi] = inputs[ord];
                if !first {
                    self.toggles[gi] += 1;
                }
                for &fo in &self.fanout[gi] {
                    if !self.dirty[fo as usize] {
                        self.dirty[fo as usize] = true;
                        self.heap.push(std::cmp::Reverse(fo));
                    }
                }
            }
        }
        // Forward settle in topological (index) order over a min-ordered
        // worklist — O(changed cone · log) instead of scanning every gate
        // per vector (the scan dominated at small cones; see EXPERIMENTS.md
        // §Perf: 0.05 → ~1 M vectors/s on the 16-bit multiplier).
        if first {
            // initialization: evaluate everything once, index order
            for gi in 0..self.nl.gates().len() {
                self.dirty[gi] = false;
                if matches!(self.nl.gates()[gi].kind, GateKind::Input) {
                    continue;
                }
                self.events += 1;
                let new = self.eval_gate(gi);
                self.values[gi] = new;
            }
            self.heap.clear();
        } else if changed_inputs >= 4 {
            // Wide cone: a linear scan beats heap traffic (random operand
            // streams toggle most of a multiplier every cycle).
            self.heap.clear();
            for gi in 0..self.nl.gates().len() {
                if !self.dirty[gi] {
                    continue;
                }
                self.dirty[gi] = false;
                if matches!(self.nl.gates()[gi].kind, GateKind::Input) {
                    continue;
                }
                self.events += 1;
                let new = self.eval_gate(gi);
                if new != self.values[gi] {
                    self.values[gi] = new;
                    self.toggles[gi] += 1;
                    for &fo in &self.fanout[gi] {
                        self.dirty[fo as usize] = true;
                    }
                }
            }
        } else {
            // Narrow cone: min-ordered worklist, O(cone · log cone).
            while let Some(std::cmp::Reverse(gi_u32)) = self.heap.pop() {
                let gi = gi_u32 as usize;
                if !self.dirty[gi] {
                    continue; // stale heap entry
                }
                self.dirty[gi] = false;
                if matches!(self.nl.gates()[gi].kind, GateKind::Input) {
                    continue;
                }
                self.events += 1;
                let new = self.eval_gate(gi);
                if new != self.values[gi] {
                    self.values[gi] = new;
                    self.toggles[gi] += 1;
                    for &fo in &self.fanout[gi] {
                        if !self.dirty[fo as usize] {
                            self.dirty[fo as usize] = true;
                            self.heap.push(std::cmp::Reverse(fo));
                        }
                    }
                }
            }
        }
        self.vectors += 1;
        self.nl
            .outputs()
            .iter()
            .map(|(_, id)| self.values[id.idx()])
            .collect()
    }

    /// Apply a vector given as unsigned operand words (same grouping rules
    /// as [`Netlist::eval_uint`]). Returns the output words.
    pub fn step_uint(
        &mut self,
        operands: &std::collections::BTreeMap<String, u64>,
    ) -> std::collections::BTreeMap<String, u64> {
        let mut bits = Vec::with_capacity(self.input_gates.len());
        let mut counters: std::collections::BTreeMap<String, u32> = Default::default();
        for (name, _) in self.nl.inputs() {
            let group = name.split('[').next().unwrap().to_string();
            let bit = counters.entry(group.clone()).or_insert(0);
            let val = operands
                .get(&group)
                .unwrap_or_else(|| panic!("missing operand {group}"));
            bits.push((val >> *bit) & 1 != 0);
            *bit += 1;
        }
        let out_bits = self.step(&bits);
        let mut outs: std::collections::BTreeMap<String, u64> = Default::default();
        let mut counters: std::collections::BTreeMap<String, u32> = Default::default();
        for ((name, _), b) in self.nl.outputs().iter().zip(out_bits) {
            let group = name.split('[').next().unwrap().to_string();
            let bit = counters.entry(group.clone()).or_insert(0);
            let e = outs.entry(group).or_insert(0);
            if b {
                *e |= 1 << *bit;
            }
            *bit += 1;
        }
        outs
    }

    pub fn toggles(&self) -> &[u64] {
        &self.toggles
    }

    pub fn total_toggles(&self) -> u64 {
        self.toggles.iter().sum()
    }

    pub fn vectors(&self) -> u64 {
        self.vectors
    }
}

impl Simulator for EventSim<'_> {
    fn name(&self) -> &'static str {
        "event-driven"
    }

    fn run(&mut self, vectors: &[Vec<bool>]) -> Vec<Vec<bool>> {
        vectors.iter().map(|v| self.step(v)).collect()
    }

    fn toggles(&self) -> &[u64] {
        &self.toggles
    }

    fn vectors(&self) -> u64 {
        self.vectors
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gates::Builder;
    use std::collections::BTreeMap;

    fn mult4() -> Netlist {
        crate::mult::pptree::build_exact(4)
    }

    #[test]
    fn functional_equivalence_with_batch_eval() {
        let nl = mult4();
        let mut sim = EventSim::new(&nl);
        for a in 0..16u64 {
            for b in 0..16u64 {
                let mut ops = BTreeMap::new();
                ops.insert("a".to_string(), a);
                ops.insert("b".to_string(), b);
                let out = sim.step_uint(&ops);
                assert_eq!(out["p"], a * b, "{a}*{b}");
            }
        }
    }

    #[test]
    fn event_counts_less_than_full_reeval() {
        let nl = mult4();
        let mut sim = EventSim::new(&nl);
        let mut ops = BTreeMap::new();
        ops.insert("a".to_string(), 5u64);
        ops.insert("b".to_string(), 9u64);
        sim.step_uint(&ops);
        let events_after_init = sim.events;
        // Change one input bit: far fewer gate evals than the whole netlist.
        ops.insert("b".to_string(), 8u64); // flips one bit
        sim.step_uint(&ops);
        let delta = sim.events - events_after_init;
        assert!(
            delta < nl.gates().len() as u64 / 2,
            "incremental step evaluated {delta} of {} gates",
            nl.gates().len()
        );
    }

    #[test]
    fn no_input_change_means_no_events() {
        let nl = mult4();
        let mut sim = EventSim::new(&nl);
        let mut ops = BTreeMap::new();
        ops.insert("a".to_string(), 7u64);
        ops.insert("b".to_string(), 3u64);
        sim.step_uint(&ops);
        let e0 = sim.events;
        let t0 = sim.total_toggles();
        sim.step_uint(&ops);
        assert_eq!(sim.events, e0);
        assert_eq!(sim.total_toggles(), t0);
    }

    #[test]
    fn toggle_counts_match_value_changes() {
        // Simple inverter chain: every input toggle propagates everywhere.
        let mut b = Builder::new("chain");
        let x = b.input("x[0]");
        let n1 = b.not(x);
        let n2 = b.not(n1);
        b.output_bit("y[0]", n2);
        let nl = b.finish();
        let mut sim = EventSim::new(&nl);
        sim.step(&[false]);
        sim.step(&[true]);
        sim.step(&[false]);
        sim.step(&[true]);
        // 3 transitions on each of the 3 nets (x, n1, n2).
        assert_eq!(sim.total_toggles(), 9);
    }
}
