//! Bit-parallel switching-activity extraction.
//!
//! A vector *stream* v₀, v₁, …, v_T is applied to the netlist; the toggle
//! count of a net is the number of t where its value differs between
//! consecutive vectors. We pack 64 consecutive vectors into the 64 lanes of
//! one bit-parallel evaluation, then count intra-word transitions with
//! `popcount(x ^ (x << 1))` and stitch word boundaries with the previous
//! word's last lane.

use crate::gates::Netlist;

/// Switching-activity result for one workload.
#[derive(Clone, Debug)]
pub struct ActivityReport {
    /// Toggle count per net (indexed by `NetId`).
    pub toggles: Vec<u64>,
    /// Number of vector *transitions* observed (vectors − 1).
    pub transitions: u64,
}

impl ActivityReport {
    pub fn total(&self) -> u64 {
        self.toggles.iter().sum()
    }

    /// Mean switching activity per net per transition (α in the dynamic
    /// power model P = α·C·V²·f).
    pub fn mean_alpha(&self) -> f64 {
        if self.transitions == 0 || self.toggles.is_empty() {
            return 0.0;
        }
        self.total() as f64 / (self.toggles.len() as f64 * self.transitions as f64)
    }
}

/// Run a stream of input vectors (each a `Vec<u64>` of operand words per
/// primary-input *bit*, i.e. already bit-expanded lane-packed input is
/// produced internally) and count toggles per net.
///
/// `vector_bits[t]` is the t-th vector as one `bool` per primary input, in
/// declaration order. The stream is processed 64 vectors per batch.
pub fn activity_bitparallel(nl: &Netlist, vector_bits: &[Vec<bool>]) -> ActivityReport {
    let n_inputs = nl.inputs().len();
    let n_nets = nl.gates().len();
    let mut toggles = vec![0u64; n_nets];
    if vector_bits.is_empty() {
        return ActivityReport {
            toggles,
            transitions: 0,
        };
    }
    let mut prev_last: Option<Vec<bool>> = None;
    let mut t = 0usize;
    while t < vector_bits.len() {
        let batch_end = (t + 64).min(vector_bits.len());
        let lanes = batch_end - t;
        // Pack: lane l = vector t+l.
        let mut assignment = vec![0u64; n_inputs];
        for (l, vec) in vector_bits[t..batch_end].iter().enumerate() {
            assert_eq!(vec.len(), n_inputs, "vector arity");
            for (i, &bit) in vec.iter().enumerate() {
                if bit {
                    assignment[i] |= 1u64 << l;
                }
            }
        }
        let vals = nl.eval_u64(&assignment);
        let mask = if lanes == 64 {
            u64::MAX
        } else {
            (1u64 << lanes) - 1
        };
        // Intra-word transitions: lane l vs lane l+1 → bits of (x ^ (x>>1))
        // restricted to lanes 0..lanes-1.
        let intra_mask = mask >> 1;
        for (net, &x) in vals.iter().enumerate() {
            let x = x & mask;
            toggles[net] += ((x ^ (x >> 1)) & intra_mask).count_ones() as u64;
        }
        // Boundary with previous batch: compare prev last lane vs lane 0.
        if let Some(prev) = &prev_last {
            // Re-evaluate lane-0 values bitwise from vals (lane 0 bit).
            for (net, &x) in vals.iter().enumerate() {
                let lane0 = x & 1 != 0;
                if lane0 != prev[net] {
                    toggles[net] += 1;
                }
            }
        }
        // Record last lane values for the next boundary.
        let last_bit = lanes - 1;
        prev_last = Some(
            vals.iter()
                .map(|&x| (x >> last_bit) & 1 != 0)
                .collect(),
        );
        t = batch_end;
    }
    ActivityReport {
        toggles,
        transitions: (vector_bits.len() - 1) as u64,
    }
}

/// Helper: build the bit-expanded vector stream for a 2-operand multiplier
/// workload `(a_t, b_t)` with `bits`-bit operands.
pub fn mult_workload_vectors(bits: usize, pairs: &[(u64, u64)]) -> Vec<Vec<bool>> {
    pairs
        .iter()
        .map(|&(a, b)| {
            let mut v = Vec::with_capacity(2 * bits);
            for i in 0..bits {
                v.push((a >> i) & 1 != 0);
            }
            for i in 0..bits {
                v.push((b >> i) & 1 != 0);
            }
            v
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::event::EventSim;
    use crate::util::rng::Pcg32;

    #[test]
    fn bitparallel_matches_event_driven_toggles() {
        let nl = crate::mult::pptree::build_exact(6);
        let mut rng = Pcg32::new(0xAC71);
        let pairs: Vec<(u64, u64)> = (0..300)
            .map(|_| (rng.below(64) as u64, rng.below(64) as u64))
            .collect();
        let vectors = mult_workload_vectors(6, &pairs);
        let bp = activity_bitparallel(&nl, &vectors);

        let mut ev = EventSim::new(&nl);
        for v in &vectors {
            ev.step(v);
        }
        assert_eq!(bp.transitions, (vectors.len() - 1) as u64);
        assert_eq!(
            bp.toggles,
            ev.toggles(),
            "bit-parallel and event-driven toggle counts must agree"
        );
    }

    #[test]
    fn constant_stream_has_zero_toggles() {
        let nl = crate::mult::pptree::build_exact(4);
        let vectors = mult_workload_vectors(4, &[(5, 9); 100]);
        let r = activity_bitparallel(&nl, &vectors);
        assert_eq!(r.total(), 0);
    }

    #[test]
    fn alternating_stream_toggles_every_transition() {
        let nl = crate::mult::pptree::build_exact(4);
        let pairs: Vec<(u64, u64)> = (0..129)
            .map(|t| if t % 2 == 0 { (0, 0) } else { (15, 15) })
            .collect();
        let vectors = mult_workload_vectors(4, &pairs);
        let r = activity_bitparallel(&nl, &vectors);
        // Primary input nets toggle on every transition (128 transitions,
        // 8 input bits).
        let input_toggles: u64 = nl
            .inputs()
            .iter()
            .map(|(_, id)| r.toggles[id.idx()])
            .sum();
        assert_eq!(input_toggles, 128 * 8);
    }

    #[test]
    fn batch_boundary_counted_once() {
        // 65 vectors forces a boundary between word 0 (64 lanes) and word 1.
        let nl = crate::mult::pptree::build_exact(4);
        let pairs: Vec<(u64, u64)> = (0..65).map(|t| ((t % 16) as u64, 7)).collect();
        let vectors = mult_workload_vectors(4, &pairs);
        let bp = activity_bitparallel(&nl, &vectors);
        let mut ev = EventSim::new(&nl);
        for v in &vectors {
            ev.step(v);
        }
        assert_eq!(bp.toggles, ev.toggles());
    }

    #[test]
    fn mean_alpha_sane() {
        let nl = crate::mult::pptree::build_exact(8);
        let mut rng = Pcg32::new(9);
        let pairs: Vec<(u64, u64)> = (0..500)
            .map(|_| (rng.below(256) as u64, rng.below(256) as u64))
            .collect();
        let r = activity_bitparallel(&nl, &mult_workload_vectors(8, &pairs));
        let alpha = r.mean_alpha();
        assert!(alpha > 0.05 && alpha < 1.0, "alpha {alpha}");
    }
}
