//! Switching-activity extraction on top of the bit-parallel engine.
//!
//! A vector *stream* v₀, v₁, …, v_T is applied to the netlist; the toggle
//! count of a net is the number of t where its value differs between
//! consecutive vectors. The heavy lifting lives in
//! [`super::bitparallel::BitParallelSim`] (64 vectors per topological sweep,
//! toggles via XOR/popcount); this module adds the workload helpers, the
//! [`ActivityReport`] consumed by the power model, and a multi-threaded
//! extractor that splits the stream across cores with one-vector overlap so
//! the merged counts stay bit-identical to a sequential run.

use super::bitparallel::BitParallelSim;
use crate::gates::Netlist;
use crate::util::threadpool::parallel_map;

/// Switching-activity result for one workload.
#[derive(Clone, Debug)]
pub struct ActivityReport {
    /// Toggle count per net (indexed by `NetId`).
    pub toggles: Vec<u64>,
    /// Number of vector *transitions* observed (vectors − 1).
    pub transitions: u64,
}

impl ActivityReport {
    pub fn total(&self) -> u64 {
        self.toggles.iter().sum()
    }

    /// Mean switching activity per net per transition (α in the dynamic
    /// power model P = α·C·V²·f).
    pub fn mean_alpha(&self) -> f64 {
        if self.transitions == 0 || self.toggles.is_empty() {
            return 0.0;
        }
        self.total() as f64 / (self.toggles.len() as f64 * self.transitions as f64)
    }
}

/// Run a stream of input vectors through the bit-parallel engine and count
/// toggles per net. `vector_bits[t]` is the t-th vector as one `bool` per
/// primary input, in declaration order. Batches go through the engine's
/// output-free [`BitParallelSim::run_bools`] path — activity extraction
/// only reads toggle counts, so no per-vector output data is materialized.
/// Each sweep covers `64 × plane_words` vectors at the SIMD tier
/// [`crate::util::simd::detect`] reports; the counts are bit-identical for
/// any width (`rust/tests/sim_equivalence.rs`).
pub fn activity_bitparallel(nl: &Netlist, vector_bits: &[Vec<bool>]) -> ActivityReport {
    if vector_bits.is_empty() {
        return ActivityReport {
            toggles: vec![0u64; nl.gates().len()],
            transitions: 0,
        };
    }
    let mut sim = BitParallelSim::new(nl);
    let sweep = 64 * crate::util::simd::detect().plane_words();
    for batch in vector_bits.chunks(sweep) {
        sim.run_bools(batch);
    }
    ActivityReport {
        transitions: (vector_bits.len() - 1) as u64,
        toggles: sim.toggles().to_vec(),
    }
}

/// Multi-threaded [`activity_bitparallel`]: the stream is split into
/// `threads` contiguous chunks, each chunk is simulated with a one-vector
/// overlap into its predecessor (so every consecutive-vector transition is
/// counted exactly once, by exactly one worker), and the per-net counts are
/// summed. Bit-identical to the sequential run for any thread count.
pub fn activity_parallel(nl: &Netlist, vector_bits: &[Vec<bool>], threads: usize) -> ActivityReport {
    let n = vector_bits.len();
    let threads = threads.max(1);
    if threads == 1 || n < 2 * threads {
        return activity_bitparallel(nl, vector_bits);
    }
    let chunk = n.div_ceil(threads);
    let parts = parallel_map(threads, threads, |ci| {
        let start = ci * chunk;
        let end = (start + chunk).min(n);
        if start >= n {
            return vec![0u64; nl.gates().len()];
        }
        // Overlap one vector backwards: this worker owns the transitions
        // landing on vectors start..end (worker 0 owns 1..end).
        let from = start.saturating_sub(1);
        activity_bitparallel(nl, &vector_bits[from..end]).toggles
    });
    let mut toggles = vec![0u64; nl.gates().len()];
    for part in parts {
        for (t, p) in toggles.iter_mut().zip(part) {
            *t += p;
        }
    }
    ActivityReport {
        toggles,
        transitions: (n - 1) as u64,
    }
}

/// Helper: build the bit-expanded vector stream for a 2-operand multiplier
/// workload `(a_t, b_t)` with `bits`-bit operands.
pub fn mult_workload_vectors(bits: usize, pairs: &[(u64, u64)]) -> Vec<Vec<bool>> {
    pairs
        .iter()
        .map(|&(a, b)| {
            let mut v = Vec::with_capacity(2 * bits);
            for i in 0..bits {
                v.push((a >> i) & 1 != 0);
            }
            for i in 0..bits {
                v.push((b >> i) & 1 != 0);
            }
            v
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::event::EventSim;
    use crate::util::rng::Pcg32;

    #[test]
    fn bitparallel_matches_event_driven_toggles() {
        let nl = crate::mult::pptree::build_exact(6);
        let mut rng = Pcg32::new(0xAC71);
        let pairs: Vec<(u64, u64)> = (0..300)
            .map(|_| (rng.below(64) as u64, rng.below(64) as u64))
            .collect();
        let vectors = mult_workload_vectors(6, &pairs);
        let bp = activity_bitparallel(&nl, &vectors);

        let mut ev = EventSim::new(&nl);
        for v in &vectors {
            ev.step(v);
        }
        assert_eq!(bp.transitions, (vectors.len() - 1) as u64);
        assert_eq!(
            bp.toggles,
            ev.toggles(),
            "bit-parallel and event-driven toggle counts must agree"
        );
    }

    #[test]
    fn parallel_matches_sequential_for_any_thread_count() {
        let nl = crate::mult::pptree::build_exact(5);
        let mut rng = Pcg32::new(0x9A7);
        let pairs: Vec<(u64, u64)> = (0..257)
            .map(|_| (rng.below(32) as u64, rng.below(32) as u64))
            .collect();
        let vectors = mult_workload_vectors(5, &pairs);
        let seq = activity_bitparallel(&nl, &vectors);
        for threads in [1, 2, 3, 4, 7] {
            let par = activity_parallel(&nl, &vectors, threads);
            assert_eq!(par.toggles, seq.toggles, "threads={threads}");
            assert_eq!(par.transitions, seq.transitions);
        }
    }

    #[test]
    fn constant_stream_has_zero_toggles() {
        let nl = crate::mult::pptree::build_exact(4);
        let vectors = mult_workload_vectors(4, &[(5, 9); 100]);
        let r = activity_bitparallel(&nl, &vectors);
        assert_eq!(r.total(), 0);
    }

    #[test]
    fn alternating_stream_toggles_every_transition() {
        let nl = crate::mult::pptree::build_exact(4);
        let pairs: Vec<(u64, u64)> = (0..129)
            .map(|t| if t % 2 == 0 { (0, 0) } else { (15, 15) })
            .collect();
        let vectors = mult_workload_vectors(4, &pairs);
        let r = activity_bitparallel(&nl, &vectors);
        // Primary input nets toggle on every transition (128 transitions,
        // 8 input bits).
        let input_toggles: u64 = nl
            .inputs()
            .iter()
            .map(|(_, id)| r.toggles[id.idx()])
            .sum();
        assert_eq!(input_toggles, 128 * 8);
    }

    #[test]
    fn batch_boundary_counted_once() {
        // 65 vectors forces a boundary between word 0 (64 lanes) and word 1.
        let nl = crate::mult::pptree::build_exact(4);
        let pairs: Vec<(u64, u64)> = (0..65).map(|t| ((t % 16) as u64, 7)).collect();
        let vectors = mult_workload_vectors(4, &pairs);
        let bp = activity_bitparallel(&nl, &vectors);
        let mut ev = EventSim::new(&nl);
        for v in &vectors {
            ev.step(v);
        }
        assert_eq!(bp.toggles, ev.toggles());
    }

    #[test]
    fn mean_alpha_sane() {
        let nl = crate::mult::pptree::build_exact(8);
        let mut rng = Pcg32::new(9);
        let pairs: Vec<(u64, u64)> = (0..500)
            .map(|_| (rng.below(256) as u64, rng.below(256) as u64))
            .collect();
        let r = activity_bitparallel(&nl, &mult_workload_vectors(8, &pairs));
        let alpha = r.mean_alpha();
        assert!(alpha > 0.05 && alpha < 1.0, "alpha {alpha}");
    }
}
