//! Engine equivalence: the bit-parallel simulator must be *bit-identical*
//! to the scalar event-driven reference — outputs AND per-net toggle
//! counts — for every paper multiplier family, exhaustively at 8 bits
//! (all 65,536 input pairs). This is the proof obligation behind routing
//! error metrics, activity/power and the DSE sweep through the
//! bit-parallel engine (see `benches/hotpaths.rs` for the speedup it buys).
//!
//! The SIMD half of the suite pins the plane-group widening (DESIGN.md
//! §"SIMD kernels"): every plane width — scalar 1-word, the NEON 2-word
//! and AVX2 4-word layouts, and the dynamic N-word path — must reproduce
//! the scalar engine's outputs and toggle counts bit for bit, and the
//! width-parameterized consumers (exhaustive error characterization,
//! functional-yield MC) must report identical numbers at every width.
//! Widths beyond the host's SIMD tier still run (the const-generic
//! fallback bodies are always compiled); a message notes when no vector
//! unit was detected so the intrinsic paths themselves were not exercised.

use openacm::config::spec::MultSpec;
use openacm::mult::behavioral::paper_families;
use openacm::mult::build_netlist;
use openacm::sim::{BitParallelSim, EventSim, Simulator};
use openacm::util::simd::{available_levels, detect, SimdLevel};

const BITS: usize = 8;

/// All 2^16 input vectors in a fixed order (a outer, b inner).
fn exhaustive_vectors() -> Vec<Vec<bool>> {
    let n = 1u64 << BITS;
    let mut vectors = Vec::with_capacity((n * n) as usize);
    for a in 0..n {
        for b in 0..n {
            let mut v = Vec::with_capacity(2 * BITS);
            for i in 0..BITS {
                v.push((a >> i) & 1 != 0);
            }
            for i in 0..BITS {
                v.push((b >> i) & 1 != 0);
            }
            vectors.push(v);
        }
    }
    vectors
}

#[test]
fn bitparallel_is_bit_identical_to_event_sim_for_all_paper_families() {
    let vectors = exhaustive_vectors();
    for (name, family) in paper_families() {
        let nl = build_netlist(&MultSpec {
            family,
            bits: BITS,
            signed: false,
        });
        let mut scalar = EventSim::new(&nl);
        let mut lanes = BitParallelSim::new(&nl);
        // Stream in chunks so cross-batch/cross-call boundaries are
        // exercised too (not only the aligned 64-lane fast path).
        let mut cursor = 0usize;
        for chunk_len in [1usize, 63, 64, 65, 1000, usize::MAX] {
            let end = cursor.saturating_add(chunk_len).min(vectors.len());
            if cursor >= end {
                break;
            }
            let slice = &vectors[cursor..end];
            let scalar_out = Simulator::run(&mut scalar, slice);
            let lanes_out = Simulator::run(&mut lanes, slice);
            assert_eq!(
                scalar_out, lanes_out,
                "{name}: outputs diverged in chunk at {cursor}"
            );
            cursor = end;
        }
        assert_eq!(cursor, vectors.len(), "exhaustive sweep incomplete");
        assert_eq!(
            Simulator::vectors(&scalar),
            (1u64 << (2 * BITS)),
            "{name}: vector count"
        );
        assert_eq!(
            Simulator::toggles(&scalar),
            Simulator::toggles(&lanes),
            "{name}: per-net toggle counts diverged"
        );
    }
}

/// Pseudorandom bool vectors (deterministic, engine-independent).
fn random_vectors(n_inputs: usize, n: usize, seed: u64) -> Vec<Vec<bool>> {
    let mut rng = openacm::util::rng::Pcg32::new(seed);
    (0..n)
        .map(|_| (0..n_inputs).map(|_| rng.next_u32() & 1 != 0).collect())
        .collect()
}

/// One message when the host has no vector unit (or `OPENACM_FORCE_SCALAR`
/// pinned dispatch): the width-N layouts below still run through the
/// always-compiled fallback bodies, but the AVX2/NEON intrinsic paths are
/// not reached on this host.
fn note_if_scalar_only() {
    let levels = available_levels();
    if levels.len() == 1 {
        println!(
            "note: SIMD level {:?} only (no AVX2/NEON detected or forced scalar) — \
             wide-plane layouts run through the portable fallback bodies",
            levels[0].name()
        );
    } else {
        let names: Vec<_> = levels.iter().map(|l| l.name()).collect();
        println!("SIMD levels under test: {names:?}");
    }
}

#[test]
fn wide_plane_widths_match_event_sim_for_all_paper_families() {
    note_if_scalar_only();
    // Widths: the scalar oracle, both fixed SIMD layouts, a dyn-path
    // width, and whatever the host detects (redundant when scalar).
    let mut widths = vec![1usize, 2, 4, 3];
    let host = detect().plane_words();
    if !widths.contains(&host) {
        widths.push(host);
    }
    for (name, family) in paper_families() {
        let nl = build_netlist(&MultSpec {
            family,
            bits: BITS,
            signed: false,
        });
        // 517 vectors: multiple sweeps per width plus a ragged tail so the
        // final sweep has a partial plane-group at every width.
        let vectors = random_vectors(nl.inputs().len(), 517, 0x51D + BITS as u64);
        let mut ev = EventSim::new(&nl);
        Simulator::run(&mut ev, &vectors);
        for &words in &widths {
            let mut bp = BitParallelSim::new(&nl);
            for chunk in vectors.chunks(64 * words) {
                bp.run_bools(chunk);
            }
            assert_eq!(
                bp.toggles(),
                Simulator::toggles(&ev),
                "{name}: width-{words} toggle counts diverged from EventSim"
            );
            assert_eq!(bp.vectors(), vectors.len() as u64, "{name} width {words}");
        }
    }
}

#[test]
fn exhaustive_error_reports_identical_at_every_plane_width() {
    note_if_scalar_only();
    use openacm::mult::error_metrics::{exhaustive_netlist, exhaustive_netlist_words};
    for (name, family) in paper_families() {
        let auto = exhaustive_netlist(&family, BITS, 2);
        for words in [1usize, 2, 4] {
            let r = exhaustive_netlist_words(&family, BITS, 2, words);
            assert_eq!(r.samples, auto.samples, "{name} words={words}");
            assert_eq!(r.error_rate.to_bits(), auto.error_rate.to_bits(), "{name} words={words}");
            assert_eq!(r.nmed.to_bits(), auto.nmed.to_bits(), "{name} words={words}");
            assert_eq!(r.mred.to_bits(), auto.mred.to_bits(), "{name} words={words}");
            assert_eq!(r.wce, auto.wce, "{name} words={words}");
            assert_eq!(
                r.normalized_bias.to_bits(),
                auto.normalized_bias.to_bits(),
                "{name} words={words}"
            );
        }
    }
}

#[test]
fn functional_yield_mc_identical_at_every_plane_width() {
    note_if_scalar_only();
    use openacm::yield_analysis::functional::{run_functional_mc_words, FunctionalYieldProblem};
    let nl = build_netlist(&MultSpec {
        family: openacm::config::spec::MultFamily::Exact,
        bits: 6,
        signed: false,
    });
    let mut rng = openacm::util::rng::Pcg32::new(0xF1E1D);
    let workload: Vec<(u64, u64)> = (0..25)
        .map(|_| (rng.below(64) as u64, rng.below(64) as u64))
        .collect();
    let problem = FunctionalYieldProblem::new(&nl, 6, vec![0.04; 6], workload, 4e-3);
    let scalar = run_functional_mc_words(&problem, 900, 0xCAFE, 2, 1);
    for words in [2usize, 3, 4] {
        let wide = run_functional_mc_words(&problem, 900, 0xCAFE, 2, words);
        assert_eq!(scalar.failures, wide.failures, "words={words}");
        assert_eq!(scalar.pf.to_bits(), wide.pf.to_bits(), "words={words}");
        assert_eq!(scalar.sims, wide.sims, "words={words}");
    }
}

#[test]
fn forced_scalar_env_pins_the_scalar_level() {
    // detect() caches on first use, so we can't toggle the env var inside
    // one process — but we can assert the dispatch/env contract that CI's
    // forced-scalar arm relies on: available_levels() always leads with
    // Scalar, and when OPENACM_FORCE_SCALAR is set (as in that CI arm)
    // detection reports Scalar with a one-word plane group.
    let levels = available_levels();
    assert_eq!(levels[0], SimdLevel::Scalar);
    assert_eq!(SimdLevel::Scalar.plane_words(), 1);
    if std::env::var("OPENACM_FORCE_SCALAR").map(|v| v == "1").unwrap_or(false) {
        assert_eq!(detect(), SimdLevel::Scalar, "OPENACM_FORCE_SCALAR=1 must pin scalar");
        assert_eq!(levels.len(), 1);
    }
}

#[test]
fn engines_report_their_names() {
    let nl = build_netlist(&MultSpec {
        family: openacm::config::spec::MultFamily::Exact,
        bits: 4,
        signed: false,
    });
    assert_eq!(Simulator::name(&EventSim::new(&nl)), "event-driven");
    assert_eq!(Simulator::name(&BitParallelSim::new(&nl)), "bit-parallel");
}
