//! Engine equivalence: the 64-lane bit-parallel simulator must be
//! *bit-identical* to the scalar event-driven reference — outputs AND
//! per-net toggle counts — for every paper multiplier family, exhaustively
//! at 8 bits (all 65,536 input pairs). This is the proof obligation behind
//! routing error metrics, activity/power and the DSE sweep through the
//! bit-parallel engine (see `benches/hotpaths.rs` for the speedup it buys).

use openacm::config::spec::MultSpec;
use openacm::mult::behavioral::paper_families;
use openacm::mult::build_netlist;
use openacm::sim::{BitParallelSim, EventSim, Simulator};

const BITS: usize = 8;

/// All 2^16 input vectors in a fixed order (a outer, b inner).
fn exhaustive_vectors() -> Vec<Vec<bool>> {
    let n = 1u64 << BITS;
    let mut vectors = Vec::with_capacity((n * n) as usize);
    for a in 0..n {
        for b in 0..n {
            let mut v = Vec::with_capacity(2 * BITS);
            for i in 0..BITS {
                v.push((a >> i) & 1 != 0);
            }
            for i in 0..BITS {
                v.push((b >> i) & 1 != 0);
            }
            vectors.push(v);
        }
    }
    vectors
}

#[test]
fn bitparallel_is_bit_identical_to_event_sim_for_all_paper_families() {
    let vectors = exhaustive_vectors();
    for (name, family) in paper_families() {
        let nl = build_netlist(&MultSpec {
            family,
            bits: BITS,
            signed: false,
        });
        let mut scalar = EventSim::new(&nl);
        let mut lanes = BitParallelSim::new(&nl);
        // Stream in chunks so cross-batch/cross-call boundaries are
        // exercised too (not only the aligned 64-lane fast path).
        let mut cursor = 0usize;
        for chunk_len in [1usize, 63, 64, 65, 1000, usize::MAX] {
            let end = cursor.saturating_add(chunk_len).min(vectors.len());
            if cursor >= end {
                break;
            }
            let slice = &vectors[cursor..end];
            let scalar_out = Simulator::run(&mut scalar, slice);
            let lanes_out = Simulator::run(&mut lanes, slice);
            assert_eq!(
                scalar_out, lanes_out,
                "{name}: outputs diverged in chunk at {cursor}"
            );
            cursor = end;
        }
        assert_eq!(cursor, vectors.len(), "exhaustive sweep incomplete");
        assert_eq!(
            Simulator::vectors(&scalar),
            (1u64 << (2 * BITS)),
            "{name}: vector count"
        );
        assert_eq!(
            Simulator::toggles(&scalar),
            Simulator::toggles(&lanes),
            "{name}: per-net toggle counts diverged"
        );
    }
}

#[test]
fn engines_report_their_names() {
    let nl = build_netlist(&MultSpec {
        family: openacm::config::spec::MultFamily::Exact,
        bits: 4,
        signed: false,
    });
    assert_eq!(Simulator::name(&EventSim::new(&nl)), "event-driven");
    assert_eq!(Simulator::name(&BitParallelSim::new(&nl)), "bit-parallel");
}
