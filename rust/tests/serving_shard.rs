//! Sharded-serving property suite: the adversarial workload generator
//! (`util::proptest::adversarial_workload`) drives the sharded, SLO-aware
//! coordinator and every delivery is checked against hard invariants:
//!
//! * **exact accounting** — `delivered + shed + rejected == submitted`
//!   across shard counts {1, 2, 4} × all four adversarial arrival
//!   patterns, with `ok + failed == delivered` and the server's own
//!   metrics agreeing with the external count;
//! * **bit-identical deliveries** — every `Delivery::Ok` bit-matches the
//!   reference function of (serving variant, image payload): the fixture
//!   backend's pure [`fixture_logits`], and the real native backend's
//!   scalar `QuantCnn::forward`;
//! * **accuracy-class routing** — table-driven proof that the router picks
//!   the *cheapest* variant whose store-recorded calibration accuracy
//!   satisfies the class, deterministically, end to end through a live
//!   sharded server;
//! * **soak** — ≥10⁶ synthetic requests through the sharded pipeline
//!   (`--ignored`; a CI-feasible smoke slice runs by default) with zero
//!   metrics-footprint growth and sane latency percentiles;
//! * **failure modes** — expired deadlines, injected backend errors, and
//!   worker panics each fail fast with the right [`FailReason`]; a panic
//!   marks the server unhealthy (→ non-zero `openacm serve` exit) without
//!   touching sibling shards; graceful shutdown drains in-flight work.

use std::collections::{BTreeMap, HashMap};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use openacm::coordinator::batcher::BatchPolicy;
use openacm::coordinator::router::{AccuracyClass, HashRing, RoutingTable};
use openacm::coordinator::server::{
    Delivery, FailReason, InferenceServer, Request, Route, ServerConfig, SubmitError,
};
use openacm::coordinator::warmstart::warm_start_profiles;
use openacm::runtime::{fixture_logits, BackendFactory, FixtureFactory};
use openacm::util::proptest::{adversarial_workload, WorkloadSpec, ADVERSARIAL_PATTERNS};
use openacm::util::rng::Pcg32;

/// Deterministic 256-byte payload pool. The high bit (and the injection
/// bytes 0xEE/0xDD) never appear, so failure injection stays opt-in.
fn images(n: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Pcg32::new(seed);
    (0..n)
        .map(|_| (0..256).map(|_| (rng.next_u64() & 0x7f) as u8).collect())
        .collect()
}

fn bits(logits: &[f32]) -> Vec<u32> {
    logits.iter().map(|x| x.to_bits()).collect()
}

/// A policy with an SLO no healthy request will miss: these tests prove
/// accounting and bit-exactness; deadline behavior is tested explicitly
/// in `failure_modes_deadline_execute_and_unroutable_class`.
fn lax_policy(max_batch: usize) -> BatchPolicy {
    BatchPolicy {
        max_batch,
        max_wait: Duration::from_millis(1),
        slo: Duration::from_secs(60),
        ..BatchPolicy::default()
    }
}

// ---------------------------------------------------------------------------
// Accounting + bit-exactness across shards × adversarial patterns
// ---------------------------------------------------------------------------

#[test]
fn accounting_identity_holds_across_shards_and_adversarial_patterns() {
    const MENU: [&str; 4] = ["appro42", "exact", "lm", "logour"];
    let imgs = images(64, 0xACC7);
    let classes = [
        AccuracyClass::parse("best-effort").unwrap(),
        AccuracyClass::parse("bronze").unwrap(),
    ];
    for shards in [1usize, 2, 4] {
        for pattern in ADVERSARIAL_PATTERNS {
            let spec = WorkloadSpec {
                pattern,
                n: 400,
                images: imgs.len(),
                variants: MENU.len(),
                classes: classes.len(),
                ..WorkloadSpec::default()
            };
            let seed = 0xBEEF ^ shards as u64;
            let reqs = adversarial_workload(seed, &spec);
            assert_eq!(
                reqs,
                adversarial_workload(seed, &spec),
                "generator must replay byte-identically from its seed"
            );
            let server = InferenceServer::start_sharded(
                Arc::new(FixtureFactory::new(&MENU, 16)),
                ServerConfig {
                    shards,
                    policy: lax_policy(16),
                    // Small enough that burst patterns may shed; the
                    // accounting identity must hold either way.
                    queue_limit: 64,
                },
            )
            .unwrap();
            assert_eq!(server.shards(), shards);

            // Replay at maximum pressure (virtual gaps ignored). Every
            // admitted request contributes its expected (serving variant,
            // logits bit pattern) to a multiset the drain checks off.
            let (tx, rx) = channel();
            let mut admitted = 0usize;
            let mut shed = 0usize;
            let mut rejected = 0usize;
            let mut expect: HashMap<(String, Vec<u32>), i64> = HashMap::new();
            for r in &reqs {
                let (payload, route, served_by) = match r.malformed {
                    Some(size) => (
                        vec![0u8; size],
                        Route::Variant(MENU[r.variant].to_string()),
                        None,
                    ),
                    None => match r.class {
                        Some(c) => {
                            let class = classes[c % classes.len()].clone();
                            let v = server
                                .routing()
                                .select(&class)
                                .expect("exact is served, so every class routes")
                                .variant;
                            (imgs[r.image].clone(), Route::Class(class), Some(v))
                        }
                        None => {
                            let v = MENU[r.variant].to_string();
                            (imgs[r.image].clone(), Route::Variant(v.clone()), Some(v))
                        }
                    },
                };
                match server.submit(Request {
                    image: payload,
                    route,
                    slo: None,
                    respond: tx.clone(),
                }) {
                    Ok(()) => {
                        admitted += 1;
                        let v = served_by.expect("admitted requests resolved a variant");
                        let key = bits(&fixture_logits(&v, &imgs[r.image]));
                        *expect.entry((v, key)).or_insert(0) += 1;
                    }
                    Err(SubmitError::Shed { .. }) => shed += 1,
                    Err(SubmitError::Malformed(_)) => {
                        assert!(
                            r.malformed.is_some(),
                            "only generator-malformed payloads may bounce as malformed"
                        );
                        rejected += 1;
                    }
                    Err(e) => panic!("unexpected submit error: {e}"),
                }
            }
            let generated_malformed = reqs.iter().filter(|r| r.malformed.is_some()).count();
            assert_eq!(
                rejected, generated_malformed,
                "every malformed payload must be rejected at the door \
                 (shards {shards}, pattern {pattern:?})"
            );
            assert_eq!(
                admitted + shed + rejected,
                reqs.len(),
                "accounting identity (shards {shards}, pattern {pattern:?})"
            );
            assert_eq!(server.admission.shed_total(), shed);

            // Drain: exactly one delivery per admitted request, every Ok
            // bit-matching its reference logits.
            let mut ok = 0usize;
            let mut failed = 0usize;
            for i in 0..admitted {
                let d = rx.recv_timeout(Duration::from_secs(120)).unwrap_or_else(|_| {
                    panic!("delivery {i}/{admitted} lost (shards {shards}, pattern {pattern:?})")
                });
                match d {
                    Delivery::Ok(resp) => {
                        let key = (resp.variant.clone(), bits(&resp.logits));
                        let left = expect.get_mut(&key).unwrap_or_else(|| {
                            panic!(
                                "delivered logits bit-match no admitted (variant, image): \
                                 variant {}",
                                resp.variant
                            )
                        });
                        *left -= 1;
                        assert!(*left >= 0, "duplicated delivery for variant {}", resp.variant);
                        ok += 1;
                    }
                    Delivery::Failed(_) => failed += 1,
                }
            }
            assert!(rx.try_recv().is_err(), "spurious extra delivery");
            assert_eq!(ok + failed, admitted);
            assert_eq!(
                failed, 0,
                "a healthy backend under a 60s SLO must not fail deliveries"
            );
            assert!(
                expect.values().all(|&c| c == 0),
                "every admitted request must be delivered exactly once"
            );
            let snap = server.metrics.snapshot();
            assert_eq!(snap.completed, ok as u64);
            assert_eq!(snap.failed, failed as u64);
            server.shutdown();
        }
    }
}

// ---------------------------------------------------------------------------
// Native-backend bit-exactness through the sharded pipeline
// ---------------------------------------------------------------------------

#[test]
fn sharded_native_deliveries_bit_match_reference_forward() {
    use openacm::runtime::backend::synthetic_serving_setup;
    let (factory, workload) = synthetic_serving_setup(24, 42, 8, 1);
    let menu = factory.variants();
    let model = Arc::clone(factory.model());
    let luts: BTreeMap<String, Arc<Vec<i32>>> = menu
        .iter()
        .map(|v| (v.clone(), Arc::clone(factory.lut(v).expect("paper variant has a LUT"))))
        .collect();

    let server = InferenceServer::start_sharded(
        Arc::new(factory),
        ServerConfig {
            shards: 2,
            policy: lax_policy(8),
            queue_limit: 4096,
        },
    )
    .unwrap();

    // Expected multiset: the scalar reference forward of every
    // (variant, image) pair submitted.
    let mut expect: HashMap<(String, Vec<u32>), i64> = HashMap::new();
    let (tx, rx) = channel();
    let mut submitted = 0usize;
    for i in 0..workload.n_images {
        for v in &menu {
            let img = workload.image(i);
            let key = bits(&model.forward(&luts[v], img));
            *expect.entry((v.clone(), key)).or_insert(0) += 1;
            server
                .submit(Request::to_variant(img.to_vec(), v.clone(), tx.clone()))
                .unwrap();
            submitted += 1;
        }
    }
    for i in 0..submitted {
        match rx
            .recv_timeout(Duration::from_secs(120))
            .unwrap_or_else(|_| panic!("delivery {i}/{submitted} lost"))
        {
            Delivery::Ok(resp) => {
                let key = (resp.variant.clone(), bits(&resp.logits));
                let left = expect
                    .get_mut(&key)
                    .expect("delivered logits must bit-match a reference forward");
                *left -= 1;
                assert!(*left >= 0);
            }
            Delivery::Failed(reason) => panic!("delivery {i} failed: {reason}"),
        }
    }
    assert!(expect.values().all(|&c| c == 0));
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Accuracy-class routing, table-driven from store records
// ---------------------------------------------------------------------------

#[test]
fn class_routing_picks_cheapest_satisfying_variant_from_store_records() {
    use openacm::store::{
        AccuracyStats, DesignPointRecord, DesignPointStore, KeyBuilder, PpaSummary,
    };
    let dir = std::env::temp_dir().join(format!(
        "openacm_route_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let store = DesignPointStore::open(&dir).unwrap();
    let ppa = |energy: f64| PpaSummary {
        delay_ns: 5.0,
        logic_area_um2: 1.0,
        sram_area_um2: 1.0,
        pnr_area_um2: 2.0,
        power_w: 1.0,
        energy_per_op_j: energy,
        logic_power_w: 0.5,
        mult_gates: 10,
    };
    // (family, calibration top-1, energy J/op). Drops vs the 0.95 exact
    // baseline: appro42 0.05%, log-our 1.5%, lm 10%.
    let specs = [
        ("exact", 0.95, 2.5e-12),
        ("appro42[yang1x8]", 0.9495, 2.1e-12),
        ("log-our", 0.935, 1.4e-12),
        ("lm-mitchell", 0.85, 1.2e-12),
    ];
    for (i, (family, top1, energy)) in specs.iter().enumerate() {
        let label = [*family; 4].join(",");
        // The uniform compile-accuracy record (what `openacm compile`
        // persists when it measures a per-family calibration point)...
        store
            .put(
                KeyBuilder::new("serving-route-test/1").u64(2 * i as u64).finish(),
                &DesignPointRecord {
                    family: format!("compile[{label}]"),
                    bits: 8,
                    accuracy: Some(AccuracyStats {
                        top1: *top1,
                        samples: 256,
                    }),
                    ..Default::default()
                },
            )
            .unwrap();
        // ...and the PPA record supplying the energy column.
        store
            .put(
                KeyBuilder::new("serving-route-test/1").u64(2 * i as u64 + 1).finish(),
                &DesignPointRecord {
                    family: family.to_string(),
                    bits: 8,
                    rows: 16,
                    n_ops: 1000,
                    ppa: Some(ppa(*energy)),
                    ..Default::default()
                },
            )
            .unwrap();
    }

    let profiles = warm_start_profiles(&store, 8);
    let variants: Vec<String> = ["appro42", "exact", "lm", "logour"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let table = RoutingTable::from_profiles(&profiles, &variants);
    // Cheapest-first, and deterministic across rebuilds.
    let order: Vec<&str> = table.entries().iter().map(|e| e.variant.as_str()).collect();
    assert_eq!(order, ["lm", "logour", "appro42", "exact"]);
    let rebuilt = RoutingTable::from_profiles(&warm_start_profiles(&store, 8), &variants);
    assert_eq!(
        rebuilt.entries().iter().map(|e| e.variant.as_str()).collect::<Vec<_>>(),
        order,
        "table construction must be deterministic"
    );

    // Table-driven: each class must pick the CHEAPEST variant whose
    // measured drop satisfies it (never a cheaper-but-worse or a
    // costlier-but-better one).
    let cases = [
        ("best-effort", "lm"),     // everything satisfies; lm is cheapest
        ("bronze", "logour"),      // lm (10%) out; logour (1.5%) in
        ("gold", "appro42"),       // only appro42 (0.05%) and exact; appro42 cheaper
        ("exact", "exact"),        // only the drop-0 entry satisfies
    ];
    for (class, want) in cases {
        let d = table
            .select(&AccuracyClass::parse(class).unwrap())
            .unwrap_or_else(|| panic!("class {class} must be routable"));
        assert_eq!(d.variant, want, "class {class}");
        assert!(!d.fallback, "class {class} routed to a measured entry");
    }

    // End to end through a live sharded server: the response's `variant`
    // echoes the routing decision and the logits come from that variant.
    let mut server = InferenceServer::start_sharded(
        Arc::new(FixtureFactory::new(&["appro42", "exact", "lm", "logour"], 8)),
        ServerConfig {
            shards: 2,
            policy: lax_policy(8),
            queue_limit: 64,
        },
    )
    .unwrap();
    server.attach_profiles(profiles);
    let imgs = images(cases.len(), 0x0A11);
    for (i, (class, want)) in cases.iter().enumerate() {
        let resp = server
            .infer_route(
                imgs[i].clone(),
                Route::Class(AccuracyClass::parse(class).unwrap()),
                None,
            )
            .unwrap();
        assert_eq!(resp.variant, *want, "served variant for class {class}");
        assert_eq!(
            resp.logits,
            fixture_logits(want, &imgs[i]),
            "logits must come from the routed variant"
        );
    }
    server.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------------
// Soak: ≥1M requests (full mode), CI-feasible smoke slice by default
// ---------------------------------------------------------------------------

/// Push `n` requests through a `shards`-shard fixture-backed pipeline at
/// maximum pressure, retrying sheds so every request eventually transits.
/// Asserts exact accounting, zero failed deliveries, zero
/// metrics-footprint growth, and sane percentiles.
fn soak(n: usize, shards: usize) {
    const MENU: [&str; 2] = ["approx", "exact"];
    let imgs = images(64, 0x50AC ^ n as u64);
    let server = InferenceServer::start_sharded(
        Arc::new(FixtureFactory::new(&MENU, 32)),
        ServerConfig {
            shards,
            policy: lax_policy(32),
            queue_limit: 4096,
        },
    )
    .unwrap();
    let metrics = Arc::clone(&server.metrics);
    let bytes_at_boot = metrics.resident_bytes();

    let (tx, rx) = channel();
    let drainer = std::thread::spawn(move || {
        let mut ok = 0u64;
        let mut failed = 0u64;
        for i in 0..n {
            match rx
                .recv_timeout(Duration::from_secs(300))
                .unwrap_or_else(|_| panic!("soak delivery {i}/{n} lost"))
            {
                Delivery::Ok(_) => ok += 1,
                Delivery::Failed(_) => failed += 1,
            }
        }
        (ok, failed)
    });

    let mut sheds = 0u64;
    for i in 0..n {
        let img = &imgs[i % imgs.len()];
        let variant = MENU[i % MENU.len()];
        let mut spins = 0u64;
        loop {
            match server.submit(Request::to_variant(img.clone(), variant, tx.clone())) {
                Ok(()) => break,
                Err(SubmitError::Shed { .. }) => {
                    // Backpressure, not an error: yield and retry so all
                    // `n` requests transit the pipeline.
                    sheds += 1;
                    spins += 1;
                    assert!(spins < 10_000_000, "pipeline stopped draining at request {i}");
                    std::thread::yield_now();
                }
                Err(e) => panic!("soak request {i}: unexpected submit error: {e}"),
            }
        }
    }
    drop(tx);
    let (ok, failed) = drainer.join().expect("drainer thread");
    assert_eq!(ok + failed, n as u64, "exactly one delivery per request");
    assert_eq!(failed, 0, "healthy backend + lax SLO must not fail deliveries");
    assert_eq!(server.admission.shed_total() as u64, sheds);

    let snap = metrics.snapshot();
    assert_eq!(snap.completed, n as u64);
    assert!(
        snap.p50_ms <= snap.p99_ms,
        "p50 {} must not exceed p99 {}",
        snap.p50_ms,
        snap.p99_ms
    );
    assert!(snap.p99_ms.is_finite() && snap.p50_ms >= 0.0);
    // Fixed-size telemetry: a soak of any length must not grow the
    // metrics footprint by a single byte (extends the PR 7 guard to the
    // sharded path).
    assert_eq!(
        metrics.resident_bytes(),
        bytes_at_boot,
        "metrics footprint grew during a {n}-request soak"
    );
    assert!(server.healthy());
    server.shutdown();
    eprintln!(
        "soak shards={shards}: {n} requests, {sheds} sheds retried, \
         p50 {:.3} ms p99 {:.3} ms, {:.0} req/s",
        snap.p50_ms, snap.p99_ms, snap.throughput_rps
    );
}

/// CI-feasible smoke slice of the soak harness, across shard counts.
#[test]
fn soak_smoke_sharded_pipeline() {
    soak(60_000, 1);
    soak(60_000, 4);
}

/// The full million-request soak (`cargo test -- --ignored`); the CI
/// serving-soak job runs the smoke slice plus the CLI drive instead.
#[test]
#[ignore = "million-request soak: run explicitly with --ignored"]
fn soak_full_million_requests() {
    soak(1_000_000, 4);
}

// ---------------------------------------------------------------------------
// Failure modes
// ---------------------------------------------------------------------------

#[test]
fn failure_modes_deadline_execute_and_unroutable_class() {
    let factory = FixtureFactory::new(&["exact"], 8).fail_on_byte(0xEE);
    let server = InferenceServer::start_sharded(
        Arc::new(factory),
        ServerConfig {
            shards: 1,
            policy: lax_policy(8),
            queue_limit: 16,
        },
    )
    .unwrap();
    let img = images(1, 7).remove(0);

    // A deadline already expired at submit must fail in the batcher —
    // deterministically, whatever the scheduler does.
    let (tx, rx) = channel();
    server
        .submit(Request::to_variant(img.clone(), "exact", tx).with_slo(Duration::ZERO))
        .unwrap();
    match rx.recv_timeout(Duration::from_secs(30)).expect("delivery") {
        Delivery::Failed(FailReason::DeadlineExpired) => {}
        other => panic!("want DeadlineExpired, got {other:?}"),
    }

    // An injected backend error fails its batch with ExecuteFailed...
    let mut bad = img.clone();
    bad[0] = 0xEE;
    let (tx, rx) = channel();
    server.submit(Request::to_variant(bad, "exact", tx)).unwrap();
    match rx.recv_timeout(Duration::from_secs(30)).expect("delivery") {
        Delivery::Failed(FailReason::ExecuteFailed(_)) => {}
        other => panic!("want ExecuteFailed, got {other:?}"),
    }

    // ...but an error is not a panic: the worker is NOT poisoned, traffic
    // keeps flowing, and the server stays healthy.
    let resp = server.infer(img.clone(), "exact").unwrap();
    assert_eq!(resp.logits, fixture_logits("exact", &img));
    assert!(server.healthy());
    let snap = server.metrics.snapshot();
    assert_eq!(snap.failed, 2);
    assert_eq!(snap.completed, 1);
    server.shutdown();

    // A class is unroutable when no variant satisfies it and exact is not
    // on the menu: typed rejection, no delivery ever owed.
    let server = InferenceServer::start_sharded(
        Arc::new(FixtureFactory::new(&["lm"], 4)),
        ServerConfig {
            shards: 1,
            policy: lax_policy(4),
            queue_limit: 16,
        },
    )
    .unwrap();
    let (tx, _rx) = channel();
    let err = server
        .submit(Request::to_class(
            images(1, 8).remove(0),
            AccuracyClass::parse("gold").unwrap(),
            tx,
        ))
        .unwrap_err();
    assert!(matches!(err, SubmitError::Unroutable(_)), "{err}");
    server.shutdown();
}

#[test]
fn worker_panic_fails_fast_marks_unhealthy_and_spares_other_shards() {
    let factory = FixtureFactory::new(&["exact"], 8).panic_on_byte(0xDD);
    let server = InferenceServer::start_sharded(
        Arc::new(factory),
        ServerConfig {
            shards: 2,
            policy: lax_policy(8),
            queue_limit: 16,
        },
    )
    .unwrap();
    // Craft payloads that land on known shards (the server's ring is
    // HashRing::new(2) by construction).
    let ring = HashRing::new(2);
    let on_shard = |first: u8, shard: usize| -> Vec<u8> {
        let mut img = vec![0u8; 256];
        img[0] = first;
        for b in 0..=255u8 {
            img[1] = b;
            if ring.shard_for(HashRing::key_for(&img)) == shard {
                return img;
            }
        }
        panic!("no payload found for shard {shard}");
    };
    let poison = on_shard(0xDD, 0);
    let same_shard = on_shard(0x01, 0);
    let other_shard = on_shard(0x02, 1);

    // Baseline: shard 0 serves.
    let resp = server.infer(same_shard.clone(), "exact").unwrap();
    assert_eq!(resp.logits, fixture_logits("exact", &same_shard));
    assert!(server.healthy());

    // The panicked batch FAILS — it must never silently hang.
    let (tx, rx) = channel();
    server.submit(Request::to_variant(poison, "exact", tx)).unwrap();
    match rx
        .recv_timeout(Duration::from_secs(30))
        .expect("a panicked worker must still deliver a failure, not hang")
    {
        Delivery::Failed(FailReason::WorkerPanicked) => {}
        other => panic!("want WorkerPanicked, got {other:?}"),
    }

    // Health records the panic (→ `openacm serve` exits non-zero).
    let failure = server.failure().expect("health must record the panic");
    assert!(failure.contains("panic"), "{failure}");
    assert!(!server.healthy());

    // The poisoned worker fails fast instead of re-entering a possibly
    // corrupt backend...
    let (tx, rx) = channel();
    server.submit(Request::to_variant(same_shard, "exact", tx)).unwrap();
    match rx.recv_timeout(Duration::from_secs(30)).expect("delivery") {
        Delivery::Failed(FailReason::WorkerPanicked) => {}
        other => panic!("poisoned worker must fail fast, got {other:?}"),
    }

    // ...while the sibling shard keeps serving bit-correct results.
    let resp = server.infer(other_shard.clone(), "exact").unwrap();
    assert_eq!(resp.logits, fixture_logits("exact", &other_shard));
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Request tracing: tail-sampled timelines + span path attribution
// ---------------------------------------------------------------------------

/// 60k-request adversarial-mix smoke soak with tracing on: max-pressure
/// submission against a tiny queue (sheds), injected backend failures,
/// and pre-expired deadlines. **Every** shed / failed / deadline-missed
/// request must keep a complete stage timeline in the tail-sampling
/// collector and appear in the Chrome trace export — the tracing
/// tentpole's acceptance property. Assertions are scoped to this test's
/// traces via the id watermark + distinctive `trc-*` variant names, so
/// sibling tests in this binary can run concurrently.
#[test]
fn traced_soak_keeps_complete_timelines_for_every_failure() {
    use openacm::obs::trace::{collector, id_watermark};
    use openacm::obs::TraceOutcome;
    const MENU: [&str; 2] = ["trc-approx", "trc-exact"];
    const N: usize = 60_000;
    openacm::obs::set_trace_enabled(true);
    // Fix the trace epoch strictly before any stamp this test asserts on,
    // so every `t_admit` is > 0.
    let _ = openacm::obs::trace::now_us();
    std::thread::sleep(Duration::from_millis(2));
    let watermark = id_watermark();

    let server = InferenceServer::start_sharded(
        Arc::new(FixtureFactory::new(&MENU, 32).fail_on_byte(0xEE)),
        ServerConfig {
            shards: 2,
            policy: lax_policy(32),
            // Tiny on purpose: max-pressure submission must shed.
            queue_limit: 64,
        },
    )
    .unwrap();
    let imgs = images(64, 0x7A3E);
    let (tx, rx) = channel();
    let mut admitted = 0usize;
    let mut shed = 0usize;
    for i in 0..N {
        let variant = MENU[i % MENU.len()];
        let mut img = imgs[i % imgs.len()].clone();
        // Adversarial mix: every 101st request trips the injected backend
        // failure; every 97th arrives with an already-expired deadline.
        let req = if i % 101 == 0 {
            img[0] = 0xEE;
            Request::to_variant(img, variant, tx.clone())
        } else if i % 97 == 0 {
            Request::to_variant(img, variant, tx.clone()).with_slo(Duration::ZERO)
        } else {
            Request::to_variant(img, variant, tx.clone())
        };
        match server.submit(req) {
            Ok(()) => admitted += 1,
            Err(SubmitError::Shed { .. }) => shed += 1,
            Err(e) => panic!("request {i}: unexpected submit error: {e}"),
        }
    }
    drop(tx);
    let mut delivered = 0usize;
    let mut deadline_missed = 0usize;
    let mut exec_failed = 0usize;
    for i in 0..admitted {
        match rx
            .recv_timeout(Duration::from_secs(300))
            .unwrap_or_else(|_| panic!("delivery {i}/{admitted} lost"))
        {
            Delivery::Ok(_) => delivered += 1,
            Delivery::Failed(FailReason::DeadlineExpired) => deadline_missed += 1,
            Delivery::Failed(FailReason::ExecuteFailed(_)) => exec_failed += 1,
            Delivery::Failed(other) => panic!("unexpected failure: {other}"),
        }
    }
    server.shutdown();
    assert_eq!(delivered + deadline_missed + exec_failed, admitted);
    assert!(shed > 0, "max pressure against queue_limit 64 must shed");
    assert!(deadline_missed > 0 && exec_failed > 0);

    // Every failure class is fully accounted in the collector: one kept
    // timeline per shed/failed/deadline-missed request, none dropped.
    let snap = collector().snapshot();
    assert_eq!(snap.failures_dropped, 0);
    let ours: Vec<_> = snap
        .failures
        .iter()
        .filter(|t| t.id >= watermark && t.variant.starts_with("trc-"))
        .collect();
    let count = |o: TraceOutcome| ours.iter().filter(|t| t.outcome == o).count();
    assert_eq!(count(TraceOutcome::Shed), shed, "one timeline per shed");
    assert_eq!(
        count(TraceOutcome::DeadlineExpired),
        deadline_missed,
        "one timeline per deadline miss"
    );
    assert_eq!(
        count(TraceOutcome::ExecuteFailed),
        exec_failed,
        "one timeline per execute failure"
    );
    assert_eq!(ours.len(), shed + deadline_missed + exec_failed);

    // ...and each timeline is complete for its outcome: stamps cover
    // exactly the stages the request reached, in order.
    for t in &ours {
        assert!(t.id > 0 && t.t_admit > 0, "traced request must stamp admission");
        assert!(t.t_done >= t.t_admit, "completion precedes admission: {t:?}");
        assert!(t.shard < 2, "shard id out of range: {t:?}");
        match t.outcome {
            TraceOutcome::Shed => {
                assert_eq!((t.t_batch, t.t_exec_start), (0, 0), "shed before batching: {t:?}");
            }
            TraceOutcome::DeadlineExpired => {
                assert_eq!(t.t_exec_start, 0, "expired requests never execute: {t:?}");
            }
            TraceOutcome::ExecuteFailed => {
                assert!(t.t_batch >= t.t_admit && t.t_batch > 0, "{t:?}");
                assert!(t.t_exec_start > 0 && t.t_exec_end >= t.t_exec_start, "{t:?}");
                assert!(t.t_done >= t.t_exec_end, "{t:?}");
            }
            other => panic!("unexpected failure outcome {other:?}"),
        }
    }

    // The Chrome export carries every one of those timelines as stage
    // slices regrouped by `args.trace`.
    let dir = std::env::temp_dir().join(format!(
        "openacm_trace_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let path = openacm::obs::trace::export_chrome(&dir).unwrap();
    let doc = openacm::obs::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let events = doc
        .get("traceEvents")
        .and_then(openacm::obs::json::Json::as_array)
        .expect("chrome export has traceEvents");
    let mut queued: std::collections::HashSet<u64> = std::collections::HashSet::new();
    for e in events {
        let name = e.get("name").and_then(openacm::obs::json::Json::as_str);
        let id = e
            .get("args")
            .and_then(|a| a.get("trace"))
            .and_then(openacm::obs::json::Json::as_u64);
        if let (Some("queue"), Some(id)) = (name, id) {
            queued.insert(id);
        }
    }
    for t in &ours {
        assert!(
            queued.contains(&t.id),
            "failure trace {} missing from the chrome export",
            t.id
        );
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Span path attribution through the sharded pipeline: the batcher and
/// executor live on different threads, yet their spans must land in the
/// parent/child histograms `span.serve.batch.us` and
/// `span.serve.batch/execute.us` (explicit full paths), at shard counts
/// {1, 4} with concurrent submitters. The flat pre-refactor name
/// `span.execute.us` must no longer be recorded.
#[test]
fn span_paths_attribute_batch_and_execute_across_shards() {
    openacm::obs::set_trace_enabled(true);
    for shards in [1usize, 4] {
        let before = openacm::obs::snapshot();
        let count = |s: &openacm::obs::RegistrySnapshot, name: &str| {
            s.histograms.get(name).map(|h| h.count).unwrap_or(0)
        };
        let server = InferenceServer::start_sharded(
            Arc::new(FixtureFactory::new(&["exact"], 16)),
            ServerConfig {
                shards,
                policy: lax_policy(16),
                queue_limit: 4096,
            },
        )
        .unwrap();
        std::thread::scope(|scope| {
            for w in 0..4usize {
                let server = &server;
                let imgs = images(16, 0x5AA5 ^ w as u64);
                scope.spawn(move || {
                    let (tx, rx) = channel();
                    for i in 0..500usize {
                        let img = imgs[i % imgs.len()].clone();
                        loop {
                            match server.submit(Request::to_variant(img.clone(), "exact", tx.clone()))
                            {
                                Ok(()) => break,
                                Err(SubmitError::Shed { .. }) => std::thread::yield_now(),
                                Err(e) => panic!("worker {w}: {e}"),
                            }
                        }
                    }
                    drop(tx);
                    for i in 0..500usize {
                        match rx
                            .recv_timeout(Duration::from_secs(120))
                            .unwrap_or_else(|_| panic!("worker {w}: delivery {i}/500 lost"))
                        {
                            Delivery::Ok(_) => {}
                            Delivery::Failed(r) => panic!("worker {w}: delivery failed: {r}"),
                        }
                    }
                });
            }
        });
        server.shutdown();
        let after = openacm::obs::snapshot();
        assert!(
            count(&after, "span.serve.batch.us") > count(&before, "span.serve.batch.us"),
            "shards={shards}: batcher spans must record under span.serve.batch.us"
        );
        assert!(
            count(&after, "span.serve.batch/execute.us")
                > count(&before, "span.serve.batch/execute.us"),
            "shards={shards}: executor spans must parent under serve.batch"
        );
        assert_eq!(
            count(&after, "span.execute.us"),
            0,
            "the flat execute span name must be gone"
        );
    }
}

#[test]
fn graceful_shutdown_drains_in_flight_requests() {
    let server = InferenceServer::start_sharded(
        Arc::new(FixtureFactory::new(&["exact"], 8)),
        ServerConfig {
            shards: 2,
            policy: lax_policy(8),
            queue_limit: 64,
        },
    )
    .unwrap();
    let imgs = images(40, 0xD7A1);
    let (tx, rx) = channel();
    for img in &imgs {
        server
            .submit(Request::to_variant(img.clone(), "exact", tx.clone()))
            .unwrap();
    }
    drop(tx);
    // Shut down immediately: the ingress-close cascade must DRAIN every
    // queued request through execute + respond, not drop it.
    server.shutdown();
    let mut ok = 0usize;
    while let Ok(d) = rx.try_recv() {
        match d {
            Delivery::Ok(_) => ok += 1,
            Delivery::Failed(reason) => panic!("in-flight request dropped as {reason}"),
        }
    }
    assert_eq!(
        ok,
        imgs.len(),
        "graceful shutdown must deliver every in-flight request"
    );
}
