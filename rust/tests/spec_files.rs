//! The shipped spec files in `specs/` must parse, validate, and run
//! through the whole compiler front end — they are the documented entry
//! point for users.

use std::path::Path;

use openacm::config::spec::MultFamily;
use openacm::config::toml::TomlDoc;

fn specs() -> Vec<std::path::PathBuf> {
    let mut v: Vec<_> = std::fs::read_dir("specs")
        .expect("specs/ directory")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map(|e| e == "toml").unwrap_or(false))
        .collect();
    v.sort();
    assert!(!v.is_empty(), "no spec files shipped");
    v
}

#[test]
fn all_shipped_specs_parse_and_validate() {
    for path in specs() {
        let spec = TomlDoc::load(&path)
            .and_then(|d| d.to_macro_spec())
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        spec.validate().unwrap();
    }
}

#[test]
fn shipped_specs_cover_the_paper_design_points() {
    let parsed: Vec<_> = specs()
        .iter()
        .map(|p| TomlDoc::load(p).unwrap().to_macro_spec().unwrap())
        .collect();
    assert!(parsed
        .iter()
        .any(|s| s.sram.rows == 16 && matches!(s.mult.family, MultFamily::Approx42 { .. })));
    assert!(parsed
        .iter()
        .any(|s| s.sram.rows == 64 && matches!(s.mult.family, MultFamily::LogOur)));
    assert!(parsed.iter().any(|s| s.sram.banks > 1 || s.sram.mux_ratio > 1));
}

#[test]
#[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
fn specs_run_through_the_full_compiler() {
    let tmp = std::env::temp_dir().join(format!("openacm_specs_{}", std::process::id()));
    for path in specs() {
        let spec = TomlDoc::load(&path).unwrap().to_macro_spec().unwrap();
        let out = tmp.join(path.file_stem().unwrap());
        let art = openacm::flow::generate_all(&spec, Path::new(&out))
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        assert!(art.files.len() >= 10, "{}: thin bundle", path.display());
    }
    std::fs::remove_dir_all(&tmp).ok();
}
