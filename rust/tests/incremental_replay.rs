//! Suffix-replay equivalence: the compile search's incremental evaluator
//! must be a pure optimization. Seeded property tests
//! (`util::proptest::check`) drive random per-layer family assignments,
//! batch sizes {1, 32} and checkpoint depths, asserting that
//!
//! * resuming the staged forward from *every* checkpoint depth reproduces
//!   the full `forward_batch_hetero` bit-for-bit;
//! * sparse linear delta replay against the all-exact reference chain
//!   reproduces a one-layer-swap forward bit-for-bit while performing
//!   strictly fewer MAC-equivalents than the suffix it replaces;
//! * an incremental compile emits the same plan as a full-forward
//!   compile, and warm store replays stay bit-identical (covered at unit
//!   level in `compile::search`; here across real multiplier families).

use openacm::config::spec::{CompressorKind, MultFamily};
use openacm::mult::behavioral::int8_lut;
use openacm::nn::model::{
    layer_macs_per_image, synthetic_images, LayerLuts, QuantCnn, IMG, N_LAYERS,
};
use openacm::util::proptest::{check, prop_assert};

/// A small but diverse family palette: the exact multiplier, both log
/// designs, a mid-aggressiveness compressor config and a high-accuracy
/// one.
fn palette() -> Vec<(String, Vec<i32>)> {
    [
        MultFamily::Exact,
        MultFamily::LogOur,
        MultFamily::Mitchell,
        MultFamily::Approx42 {
            compressor: CompressorKind::Yang1,
            approx_cols: 8,
        },
        MultFamily::Approx42 {
            compressor: CompressorKind::Kong,
            approx_cols: 4,
        },
    ]
    .iter()
    .map(|f| (f.name(), int8_lut(f)))
    .collect()
}

fn bits_of(rows: &[Vec<f32>]) -> Vec<u32> {
    rows.iter().flatten().map(|x| x.to_bits()).collect()
}

fn luts_for<'a>(palette: &'a [(String, Vec<i32>)], asg: &[usize; N_LAYERS]) -> LayerLuts<'a> {
    LayerLuts {
        conv1: &palette[asg[0]].1,
        conv2: &palette[asg[1]].1,
        fc1: &palette[asg[2]].1,
        fc2: &palette[asg[3]].1,
    }
}

fn run_suffix_replay_cases(batches: &[usize], cases: usize, seed: u64) {
    let pal = palette();
    let model = QuantCnn::random(0xACC);
    check(cases, seed, |g| {
        let bsz = *g.choose(batches);
        let images = synthetic_images(bsz, g.u64_bits(16));
        let views: Vec<&[u8]> = images.chunks(IMG * IMG).collect();
        let mut asg = [0usize; N_LAYERS];
        for slot in asg.iter_mut() {
            *slot = g.usize_below(pal.len());
        }
        let luts = luts_for(&pal, &asg);
        let threads = 1 + g.usize_below(3);
        let full = model.forward_batch_hetero(&luts, &views, threads);

        // Replay from every depth — not just a sampled one — so a broken
        // stage boundary cannot hide behind the draw.
        let mut ck = model.input_checkpoint(&views);
        for depth in 0..N_LAYERS {
            let replay = model.resume_batch_hetero(&ck, &luts, 1);
            prop_assert(
                bits_of(&replay) == bits_of(&full),
                format!("replay from depth {depth} diverged (asg {asg:?}, bsz {bsz})"),
            )?;
            if depth < N_LAYERS - 1 {
                ck = model.advance_checkpoint(&ck, luts.get(depth), 1);
            }
        }
        Ok(())
    });
}

fn run_delta_replay_cases(batches: &[usize], cases: usize, seed: u64) {
    let pal = palette();
    let model = QuantCnn::random(0xDE17A);
    let exact_luts = LayerLuts::uniform(&pal[0].1);
    check(cases, seed, |g| {
        let bsz = *g.choose(batches);
        let images = synthetic_images(bsz, g.u64_bits(16));
        let views: Vec<&[u8]> = images.chunks(IMG * IMG).collect();
        let anchor = model.reference_chain(&exact_luts, &views, 1);
        // One non-exact layer, everything downstream exact — the shape of
        // every sensitivity probe.
        let layer = g.usize_below(N_LAYERS - 1);
        let cand = 1 + g.usize_below(pal.len() - 1);
        let mut asg = [0usize; N_LAYERS];
        asg[layer] = cand;
        let luts = luts_for(&pal, &asg);
        let full = model.forward_batch_hetero(&luts, &views, 1);
        let next = model.advance_checkpoint(anchor.checkpoint(layer), &pal[cand].1, 1);
        let (logits, dmacs) = model.delta_resume_exact(&anchor, &next);
        prop_assert(
            bits_of(&logits) == bits_of(&full),
            format!(
                "delta replay diverged (layer {layer} → {}, bsz {bsz})",
                pal[cand].0
            ),
        )?;
        // Delta cost is bounded by the full suffix (equality only if every
        // single downstream activation changed); the strict aggregate
        // saving is asserted in `compile::search`'s stats tests.
        let full_suffix: u64 =
            layer_macs_per_image()[layer + 1..].iter().sum::<u64>() * bsz as u64;
        prop_assert(
            dmacs <= full_suffix,
            format!("delta replay exceeded the full suffix: {dmacs} vs {full_suffix}"),
        )
    });
}

#[test]
fn suffix_replay_bit_identical_small_batches() {
    run_suffix_replay_cases(&[1, 4], 6, 0x51DE);
}

#[test]
#[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
fn suffix_replay_bit_identical_batch_32() {
    run_suffix_replay_cases(&[1, 32], 16, 0x51DF);
}

#[test]
fn delta_replay_bit_identical_small_batches() {
    run_delta_replay_cases(&[1, 4], 6, 0xD317);
}

#[test]
#[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
fn delta_replay_bit_identical_batch_32() {
    run_delta_replay_cases(&[1, 32], 16, 0xD318);
}
