//! Serving integration tests, in two tiers:
//!
//! * **Native-backend suite** (always runs, zero artifacts): the full
//!   coordinator — admission → batcher → execute → respond — over the
//!   batched Rust-native quantized CNN, including a 500-request soak with
//!   exact accounting, per-variant FIFO, and bit-exact logits against the
//!   scalar reference forward.
//! * **PJRT suite** (skips gracefully when `make artifacts` has not run):
//!   PJRT execution of the JAX graph, native-vs-PJRT agreement, and the
//!   coordinator over the compiled executable.

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use openacm::config::spec::MultFamily;
use openacm::coordinator::batcher::BatchPolicy;
use openacm::coordinator::server::{Delivery, InferenceServer, Request};
use openacm::mult::behavioral::int8_lut;
use openacm::nn::eval::argmax;
use openacm::nn::model::{synthetic_images, QuantCnn};
use openacm::runtime::{client, ArtifactStore, NativeFactory, Runtime};

// ---------------------------------------------------------------------------
// Native-backend suite (no artifacts, no PJRT)
// ---------------------------------------------------------------------------

/// The soak's three serving variants.
const SOAK_FAMILIES: [(&str, MultFamily); 3] = [
    ("exact", MultFamily::Exact),
    ("logour", MultFamily::LogOur),
    ("lm", MultFamily::Mitchell),
];

#[test]
fn native_soak_500_requests_accounting_fifo_and_exact_logits() {
    const N: usize = 500;
    let cnn = QuantCnn::random(11);
    let luts: BTreeMap<String, Vec<i32>> = SOAK_FAMILIES
        .iter()
        .map(|(name, fam)| (name.to_string(), int8_lut(fam)))
        .collect();
    let variant_of = |seq: usize| SOAK_FAMILIES[seq % SOAK_FAMILIES.len()].0;

    // One distinct deterministic image per request, and its reference
    // logits from the scalar forward (the bit-exactness oracle). The
    // logits' bit patterns key responses back to their request.
    let images: Vec<Vec<u8>> = (0..N)
        .map(|seq| synthetic_images(1, 0x50AC + seq as u64))
        .collect();
    let mut expect: BTreeMap<&str, HashMap<Vec<u32>, usize>> = BTreeMap::new();
    for seq in 0..N {
        let v = variant_of(seq);
        let logits = cnn.forward(&luts[v], &images[seq]);
        let key: Vec<u32> = logits.iter().map(|x| x.to_bits()).collect();
        let dup = expect.entry(v).or_default().insert(key, seq);
        assert!(dup.is_none(), "reference logits collide — change the seed");
    }

    let server = InferenceServer::start_with_backend(
        Arc::new(NativeFactory::new(cnn, luts, 32, 1)),
        BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_millis(1),
            // A generous SLO: this test asserts bit-exactness and FIFO, not
            // deadline behavior (that's rust/tests/serving_shard.rs).
            slo: Duration::from_secs(60),
            ..BatchPolicy::default()
        },
        64, // small enough that a 500-burst may shed; accounting must hold
    )
    .unwrap();
    assert_eq!(server.backend, "native");
    let metrics_bytes_at_boot = server.metrics.resident_bytes();

    // Burst all 500 submissions. Responses for one variant funnel through
    // ONE shared channel, so arrival order is exactly the worker's
    // completion order.
    let chans: BTreeMap<&str, _> = SOAK_FAMILIES
        .iter()
        .map(|(name, _)| (*name, channel()))
        .collect();
    let mut admitted: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
    let mut shed = 0usize;
    for (seq, image) in images.iter().enumerate() {
        let v = variant_of(seq);
        match server.submit(Request::to_variant(image.clone(), *v, chans[v].0.clone())) {
            Ok(()) => admitted.entry(v).or_default().push(seq),
            Err(e) => {
                assert!(e.to_string().contains("shed"), "unexpected submit error: {e:#}");
                shed += 1;
            }
        }
    }
    let admitted_total: usize = admitted.values().map(|s| s.len()).sum();
    assert_eq!(
        admitted_total + shed,
        N,
        "shed ({shed}) + admitted ({admitted_total}) must equal submitted ({N})"
    );
    assert_eq!(server.admission.shed_total(), shed);

    // Drain: every admitted request must produce exactly one response, in
    // FIFO order per variant, with logits bit-identical to the reference.
    for (v, seqs) in &admitted {
        let rx = &chans[v].1;
        let mut got = Vec::with_capacity(seqs.len());
        for i in 0..seqs.len() {
            let resp = match rx
                .recv_timeout(Duration::from_secs(60))
                .unwrap_or_else(|_| panic!("variant {v}: response {i}/{} lost", seqs.len()))
            {
                Delivery::Ok(resp) => resp,
                Delivery::Failed(reason) => panic!("variant {v}: request {i} failed: {reason}"),
            };
            assert_eq!(resp.logits.len(), 10);
            assert_eq!(resp.variant, *v, "response echoes the serving variant");
            assert_eq!(
                resp.predicted,
                argmax(&resp.logits),
                "predicted must be argmax of logits"
            );
            let key: Vec<u32> = resp.logits.iter().map(|x| x.to_bits()).collect();
            let seq = *expect[v]
                .get(&key)
                .expect("delivered logits must bit-match a reference forward");
            got.push(seq);
        }
        assert_eq!(&got, seqs, "variant {v}: FIFO violated, or a response lost/duplicated");
        assert!(
            rx.try_recv().is_err(),
            "variant {v}: spurious extra response after all admitted were served"
        );
    }
    let snap = server.metrics.snapshot();
    assert_eq!(snap.completed, admitted_total as u64);
    // Telemetry memory is fixed-size histograms, not per-request Vecs: the
    // soak must not have grown the metrics footprint at all.
    assert_eq!(
        server.metrics.resident_bytes(),
        metrics_bytes_at_boot,
        "serving metrics footprint grew during the soak"
    );
    server.shutdown();
}

#[test]
fn native_server_serves_all_paper_variants_without_artifacts() {
    use openacm::runtime::backend::synthetic_serving_setup;
    let (factory, workload) = synthetic_serving_setup(16, 42, 8, 1);
    let server = InferenceServer::start_with_backend(
        Arc::new(factory),
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            slo: Duration::from_secs(60),
            ..BatchPolicy::default()
        },
        4096,
    )
    .unwrap();
    let variants = server.variants();
    assert_eq!(
        variants,
        vec!["appro42".to_string(), "exact".into(), "lm".into(), "logour".into()],
        "BTreeMap route order"
    );
    // The exact variant must reproduce the workload labels perfectly —
    // they were defined as its own predictions.
    for i in 0..workload.n_images {
        let resp = server.infer(workload.image(i).to_vec(), "exact").unwrap();
        assert_eq!(resp.predicted, workload.labels[i], "image {i}");
    }
    // Unknown variants still bounce with a useful error.
    let (tx, _rx) = channel();
    let err = server
        .submit(Request::to_variant(vec![0; 256], "no-such-family", tx))
        .unwrap_err();
    assert!(err.to_string().contains("unknown variant"));
    // Malformed images are rejected at the door — they must never reach
    // a batch, where they would sink their batchmates' responses too.
    let (tx, _rx) = channel();
    let err = server
        .submit(Request::to_variant(vec![0; 100], "exact", tx))
        .unwrap_err();
    assert!(err.to_string().contains("256"), "{err:#}");
    // Well-formed traffic keeps flowing afterwards.
    let resp = server.infer(workload.image(0).to_vec(), "exact").unwrap();
    assert_eq!(resp.predicted, workload.labels[0]);
    server.shutdown();
}

// ---------------------------------------------------------------------------
// PJRT suite (needs `make artifacts`)
// ---------------------------------------------------------------------------

fn store() -> Option<ArtifactStore> {
    let dir = Path::new("artifacts");
    if !ArtifactStore::exists(dir) {
        eprintln!("skipping: artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(ArtifactStore::load(dir).expect("artifacts load"))
}

#[test]
fn pjrt_executes_aot_graph() {
    let Some(store) = store() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = rt.compile_hlo_text(&store.model_hlo).unwrap();
    let b = store.batch;
    let lut = store.luts.get("exact").unwrap();
    let lut_lit = client::literal_i32(&[65536], lut).unwrap();
    let mut px = vec![0i32; b * 256];
    for j in 0..b {
        for (k, &p) in store.image(j % store.n_images).iter().enumerate() {
            px[j * 256 + k] = p as i32;
        }
    }
    let img = client::literal_i32(&[b, 16, 16], &px).unwrap();
    let mut args = vec![img, lut_lit];
    args.extend(client::weight_literals(&store.weights).unwrap());
    let out = model.run_f32(&args, b * 10).unwrap();
    assert_eq!(out.len(), b * 10);
    assert!(out.iter().all(|v| v.is_finite()));
    // logits must not be constant
    let first = &out[0..10];
    assert!(first.iter().any(|&v| (v - first[0]).abs() > 1e-6));
}

#[test]
fn pjrt_and_native_forward_agree() {
    let Some(store) = store() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = rt.compile_hlo_text(&store.model_hlo).unwrap();
    let cnn = QuantCnn::load(&store.dir).unwrap();
    let b = store.batch;
    for (family, lut) in &store.luts {
        let lut_lit = client::literal_i32(&[65536], lut).unwrap();
        let mut px = vec![0i32; b * 256];
        for j in 0..b {
            for (k, &p) in store.image(j).iter().enumerate() {
                px[j * 256 + k] = p as i32;
            }
        }
        let img = client::literal_i32(&[b, 16, 16], &px).unwrap();
        let mut args = vec![img, lut_lit];
        args.extend(client::weight_literals(&store.weights).unwrap());
        let out = model.run_f32(&args, b * 10).unwrap();
        for j in 0..b.min(8) {
            let native = cnn.forward(lut, store.image(j));
            let pjrt = &out[j * 10..(j + 1) * 10];
            for (k, (&n, &p)) in native.iter().zip(pjrt).enumerate() {
                assert!(
                    (n - p).abs() < 1e-3 * (1.0 + n.abs()),
                    "{family} image {j} logit {k}: native {n} vs pjrt {p}"
                );
            }
        }
    }
}

#[test]
fn coordinator_serves_all_variants_concurrently() {
    let Some(store) = store() else { return };
    let server = InferenceServer::start(
        &store,
        BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            slo: Duration::from_secs(60),
            ..BatchPolicy::default()
        },
    )
    .unwrap();
    let variants = server.variants();
    assert!(variants.len() >= 4, "{variants:?}");

    // Fire 64 async requests across variants, collect all responses.
    let mut pending = Vec::new();
    for i in 0..64usize {
        let (tx, rx) = channel();
        let variant = variants[i % variants.len()].clone();
        server
            .submit(Request::to_variant(
                store.image(i % store.n_images).to_vec(),
                variant,
                tx,
            ))
            .unwrap();
        pending.push((i, rx));
    }
    let mut correct = 0;
    for (i, rx) in pending {
        let resp = match rx.recv_timeout(Duration::from_secs(60)).expect("response arrived") {
            Delivery::Ok(resp) => resp,
            Delivery::Failed(reason) => panic!("request {i} failed: {reason}"),
        };
        assert_eq!(resp.logits.len(), 10);
        if resp.predicted == store.labels[i % store.n_images] {
            correct += 1;
        }
    }
    // The quantized CNN is ~0.75-0.86 accurate; demand well above chance.
    assert!(correct > 32, "only {correct}/64 correct");
    let snap = server.metrics.snapshot();
    assert_eq!(snap.completed, 64);
    assert!(snap.mean_batch >= 1.0);
    server.shutdown();
}

#[test]
fn coordinator_rejects_unknown_variant() {
    let Some(store) = store() else { return };
    let server = InferenceServer::start(&store, BatchPolicy::default()).unwrap();
    let (tx, _rx) = channel();
    let err = server
        .submit(Request::to_variant(vec![0; 256], "no-such-family", tx))
        .unwrap_err();
    assert!(err.to_string().contains("unknown variant"));
    server.shutdown();
}

#[test]
fn admission_sheds_load_beyond_queue_limit() {
    let Some(store) = store() else { return };
    // Queue limit 4: the 5th concurrent submission must be shed cleanly.
    let server = InferenceServer::start_with_queue_limit(
        &store,
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(50),
            slo: Duration::from_secs(60),
            ..BatchPolicy::default()
        },
        4,
    )
    .unwrap();
    let variant = server.variants()[0].clone();
    let mut rxs = Vec::new();
    let mut shed = 0;
    for i in 0..12 {
        let (tx, rx) = channel();
        match server.submit(Request::to_variant(
            store.image(i % store.n_images).to_vec(),
            variant.clone(),
            tx,
        )) {
            Ok(()) => rxs.push(rx),
            Err(e) => {
                assert!(e.to_string().contains("shed"), "{e:#}");
                shed += 1;
            }
        }
    }
    assert!(shed > 0, "burst beyond the limit must shed");
    assert!(!rxs.is_empty());
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(60)).expect("admitted requests complete");
    }
    // Tickets are dropped by the worker just after it sends each response;
    // poll briefly rather than racing that drop.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while server.admission.depth(&variant) != 0 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(server.admission.depth(&variant), 0, "slots released");
    assert_eq!(server.admission.shed_total(), shed);
    server.shutdown();
}
