//! Integration tests over the AOT artifacts: PJRT execution of the JAX
//! graph, native-vs-PJRT agreement, and the full coordinator (routing +
//! dynamic batching) under concurrent load.
//!
//! All tests skip gracefully when `make artifacts` has not run.

use std::path::Path;
use std::sync::mpsc::channel;
use std::time::Duration;

use openacm::coordinator::batcher::BatchPolicy;
use openacm::coordinator::server::{InferenceServer, Request};
use openacm::nn::model::QuantCnn;
use openacm::runtime::{client, ArtifactStore, Runtime};

fn store() -> Option<ArtifactStore> {
    let dir = Path::new("artifacts");
    if !ArtifactStore::exists(dir) {
        eprintln!("skipping: artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(ArtifactStore::load(dir).expect("artifacts load"))
}

#[test]
fn pjrt_executes_aot_graph() {
    let Some(store) = store() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = rt.compile_hlo_text(&store.model_hlo).unwrap();
    let b = store.batch;
    let lut = store.luts.get("exact").unwrap();
    let lut_lit = client::literal_i32(&[65536], lut).unwrap();
    let mut px = vec![0i32; b * 256];
    for j in 0..b {
        for (k, &p) in store.image(j % store.n_images).iter().enumerate() {
            px[j * 256 + k] = p as i32;
        }
    }
    let img = client::literal_i32(&[b, 16, 16], &px).unwrap();
    let mut args = vec![img, lut_lit];
    args.extend(client::weight_literals(&store.weights).unwrap());
    let out = model.run_f32(&args, b * 10).unwrap();
    assert_eq!(out.len(), b * 10);
    assert!(out.iter().all(|v| v.is_finite()));
    // logits must not be constant
    let first = &out[0..10];
    assert!(first.iter().any(|&v| (v - first[0]).abs() > 1e-6));
}

#[test]
fn pjrt_and_native_forward_agree() {
    let Some(store) = store() else { return };
    let rt = Runtime::cpu().unwrap();
    let model = rt.compile_hlo_text(&store.model_hlo).unwrap();
    let cnn = QuantCnn::load(&store.dir).unwrap();
    let b = store.batch;
    for (family, lut) in &store.luts {
        let lut_lit = client::literal_i32(&[65536], lut).unwrap();
        let mut px = vec![0i32; b * 256];
        for j in 0..b {
            for (k, &p) in store.image(j).iter().enumerate() {
                px[j * 256 + k] = p as i32;
            }
        }
        let img = client::literal_i32(&[b, 16, 16], &px).unwrap();
        let mut args = vec![img, lut_lit];
        args.extend(client::weight_literals(&store.weights).unwrap());
        let out = model.run_f32(&args, b * 10).unwrap();
        for j in 0..b.min(8) {
            let native = cnn.forward(lut, store.image(j));
            let pjrt = &out[j * 10..(j + 1) * 10];
            for (k, (&n, &p)) in native.iter().zip(pjrt).enumerate() {
                assert!(
                    (n - p).abs() < 1e-3 * (1.0 + n.abs()),
                    "{family} image {j} logit {k}: native {n} vs pjrt {p}"
                );
            }
        }
    }
}

#[test]
fn coordinator_serves_all_variants_concurrently() {
    let Some(store) = store() else { return };
    let server = InferenceServer::start(
        &store,
        BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
        },
    )
    .unwrap();
    let variants = server.variants();
    assert!(variants.len() >= 4, "{variants:?}");

    // Fire 64 async requests across variants, collect all responses.
    let mut pending = Vec::new();
    for i in 0..64usize {
        let (tx, rx) = channel();
        let variant = variants[i % variants.len()].clone();
        server
            .submit(Request {
                image: store.image(i % store.n_images).to_vec(),
                variant,
                respond: tx,
            })
            .unwrap();
        pending.push((i, rx));
    }
    let mut correct = 0;
    for (i, rx) in pending {
        let resp = rx
            .recv_timeout(Duration::from_secs(60))
            .expect("response arrived");
        assert_eq!(resp.logits.len(), 10);
        if resp.predicted == store.labels[i % store.n_images] {
            correct += 1;
        }
    }
    // The quantized CNN is ~0.75-0.86 accurate; demand well above chance.
    assert!(correct > 32, "only {correct}/64 correct");
    let snap = server.metrics.snapshot();
    assert_eq!(snap.completed, 64);
    assert!(snap.mean_batch >= 1.0);
    server.shutdown();
}

#[test]
fn coordinator_rejects_unknown_variant() {
    let Some(store) = store() else { return };
    let server = InferenceServer::start(&store, BatchPolicy::default()).unwrap();
    let (tx, _rx) = channel();
    let err = server
        .submit(Request {
            image: vec![0; 256],
            variant: "no-such-family".into(),
            respond: tx,
        })
        .unwrap_err();
    assert!(err.to_string().contains("unknown variant"));
    server.shutdown();
}

#[test]
fn admission_sheds_load_beyond_queue_limit() {
    let Some(store) = store() else { return };
    // Queue limit 4: the 5th concurrent submission must be shed cleanly.
    let server = InferenceServer::start_with_queue_limit(
        &store,
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(50),
        },
        4,
    )
    .unwrap();
    let variant = server.variants()[0].clone();
    let mut rxs = Vec::new();
    let mut shed = 0;
    for i in 0..12 {
        let (tx, rx) = channel();
        match server.submit(Request {
            image: store.image(i % store.n_images).to_vec(),
            variant: variant.clone(),
            respond: tx,
        }) {
            Ok(()) => rxs.push(rx),
            Err(e) => {
                assert!(e.to_string().contains("shed"), "{e:#}");
                shed += 1;
            }
        }
    }
    assert!(shed > 0, "burst beyond the limit must shed");
    assert!(!rxs.is_empty());
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(60)).expect("admitted requests complete");
    }
    // Tickets are dropped by the worker just after it sends each response;
    // poll briefly rather than racing that drop.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while server.admission.depth(&variant) != 0 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(server.admission.depth(&variant), 0, "slots released");
    assert_eq!(server.admission.shed_total(), shed);
    server.shutdown();
}
