//! Property tests (via the in-tree `util::proptest` harness) for the
//! error-metric invariants the DSE engine leans on:
//!
//! * exact multipliers score zero on every metric,
//! * NMED is a true normalized mean (always in [0, 1]),
//! * for the tunable Appro4-2 family, the worst-case error is monotone
//!   non-decreasing in the approximation degree (the approximate-column
//!   budget) — the invariant that makes the accuracy knob a knob.

use openacm::config::spec::{CompressorKind, MultFamily};
use openacm::mult::error_metrics::{exhaustive, sampled};
use openacm::util::proptest::{check, prop_assert, Gen};

fn random_family(g: &mut Gen) -> MultFamily {
    match g.usize_below(4) {
        0 => MultFamily::Approx42 {
            compressor: *g.choose(CompressorKind::all_approx()),
            approx_cols: g.usize_below(17),
        },
        1 => MultFamily::LogOur,
        2 => MultFamily::Mitchell,
        _ => MultFamily::AdderTree,
    }
}

#[test]
fn exact_multiplier_scores_zero_on_every_metric() {
    check(12, 0xE0, |g| {
        let bits = 2 + g.usize_below(7); // 2..=8
        let r = exhaustive(&MultFamily::Exact, bits);
        prop_assert(
            r.nmed == 0.0
                && r.mred == 0.0
                && r.error_rate == 0.0
                && r.wce == 0
                && r.normalized_bias == 0.0,
            format!("exact multiplier at {bits} bits scored nonzero: {r:?}"),
        )
    });
}

#[test]
fn nmed_is_normalized_into_unit_interval() {
    check(24, 0xE1, |g| {
        let bits = 4 + g.usize_below(5); // 4..=8
        let family = random_family(g);
        let r = exhaustive(&family, bits);
        prop_assert(
            (0.0..=1.0).contains(&r.nmed) && r.nmed.is_finite(),
            format!("NMED {:.3e} outside [0,1] for {family:?} at {bits} bits", r.nmed),
        )?;
        prop_assert(
            r.error_rate >= 0.0 && r.error_rate <= 1.0,
            format!("ER {} outside [0,1]", r.error_rate),
        )?;
        prop_assert(
            r.normalized_bias.abs() <= r.nmed + 1e-12,
            format!("|bias| {:.3e} exceeds NMED {:.3e}", r.normalized_bias, r.nmed),
        )
    });
}

#[test]
fn sampled_nmed_also_normalized_for_wide_multipliers() {
    check(6, 0xE2, |g| {
        let bits = 12 + g.usize_below(9); // 12..=20
        let family = random_family(g);
        let r = sampled(&family, bits, 2_000, 0x5EED ^ bits as u64);
        prop_assert(
            (0.0..=1.0).contains(&r.nmed) && r.nmed.is_finite(),
            format!("sampled NMED {:.3e} outside [0,1] at {bits} bits", r.nmed),
        )
    });
}

#[test]
fn wce_is_monotone_in_the_approximation_degree() {
    // More approximated columns can only widen the worst case: the Fig 2
    // accuracy knob must be monotone or the DSE ordering is meaningless.
    check(20, 0xE3, |g| {
        let compressor = *g.choose(CompressorKind::all_approx());
        let lo = g.usize_below(17);
        let hi = g.usize_below(17);
        let (lo, hi) = (lo.min(hi), lo.max(hi));
        let mk = |cols| MultFamily::Approx42 {
            compressor,
            approx_cols: cols,
        };
        let wce_lo = exhaustive(&mk(lo), 8).wce;
        let wce_hi = exhaustive(&mk(hi), 8).wce;
        prop_assert(
            wce_lo <= wce_hi,
            format!("{compressor:?}: WCE({lo} cols)={wce_lo} > WCE({hi} cols)={wce_hi}"),
        )
    });
}

#[test]
fn zero_approx_columns_degrades_to_exact() {
    check(6, 0xE4, |g| {
        let compressor = *g.choose(CompressorKind::all_approx());
        let r = exhaustive(
            &MultFamily::Approx42 {
                compressor,
                approx_cols: 0,
            },
            8,
        );
        prop_assert(
            r.wce == 0 && r.nmed == 0.0,
            format!("{compressor:?} with 0 approx columns is not exact: {r:?}"),
        )
    });
}
