//! Failure injection: corrupted/incomplete artifact bundles must produce
//! clean, actionable errors — never panics or silent misbehavior — because
//! the coordinator loads these at service start.

use std::fs;
use std::path::{Path, PathBuf};

use openacm::runtime::ArtifactStore;
use openacm::util::npy::{self, NpyArray};

fn fresh_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("openacm_fi_{tag}_{}", std::process::id()));
    fs::remove_dir_all(&d).ok();
    fs::create_dir_all(&d).unwrap();
    d
}

/// Build a minimal structurally-valid artifact dir.
fn minimal_artifacts(tag: &str) -> PathBuf {
    let d = fresh_dir(tag);
    fs::write(d.join("model.hlo.txt"), "HloModule fake").unwrap();
    fs::write(d.join("manifest.txt"), "batch=32\n").unwrap();
    fs::create_dir_all(d.join("luts")).unwrap();
    let lut = NpyArray::from_i32(&[256, 256], &vec![0i32; 65536]);
    npy::write(&d.join("luts/lut_exact.npy"), &lut).unwrap();
    fs::create_dir_all(d.join("dataset")).unwrap();
    npy::write(
        &d.join("dataset/test_images.npy"),
        &NpyArray::from_u8(&[2, 16, 16], &vec![0u8; 512]),
    )
    .unwrap();
    let labels = NpyArray {
        dtype: openacm::util::npy::DType::I64,
        shape: vec![2],
        data: vec![0u8; 16],
    };
    npy::write(&d.join("dataset/test_labels.npy"), &labels).unwrap();
    fs::create_dir_all(d.join("weights")).unwrap();
    for (name, k, n) in [("conv1", 9, 8), ("conv2", 72, 16), ("fc1", 64, 32), ("fc2", 32, 10)] {
        npy::write(
            &d.join(format!("weights/{name}_q.npy")),
            &NpyArray::from_i32(&[k, n], &vec![0i32; k * n]),
        )
        .unwrap();
        npy::write(
            &d.join(format!("weights/{name}_b.npy")),
            &NpyArray::from_f32(&[n], &vec![0f32; n]),
        )
        .unwrap();
    }
    npy::write(
        &d.join("weights/scales.npy"),
        &NpyArray::from_f32(&[8], &[0.01; 8]),
    )
    .unwrap();
    d
}

#[test]
fn minimal_bundle_loads() {
    let d = minimal_artifacts("ok");
    let s = ArtifactStore::load(&d).unwrap();
    assert_eq!(s.n_images, 2);
    assert_eq!(s.batch, 32);
    assert_eq!(s.weights.len(), 8);
    fs::remove_dir_all(&d).ok();
}

#[test]
fn wrong_size_lut_is_rejected() {
    let d = minimal_artifacts("badlut");
    let bad = NpyArray::from_i32(&[16, 16], &vec![0i32; 256]);
    npy::write(&d.join("luts/lut_exact.npy"), &bad).unwrap();
    let e = ArtifactStore::load(&d).unwrap_err();
    assert!(e.to_string().contains("65536"), "{e:#}");
    fs::remove_dir_all(&d).ok();
}

#[test]
fn truncated_npy_is_rejected_not_panicking() {
    let d = minimal_artifacts("trunc");
    let path = d.join("luts/lut_exact.npy");
    let bytes = fs::read(&path).unwrap();
    fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    let e = ArtifactStore::load(&d).unwrap_err();
    assert!(
        format!("{e:#}").contains("truncated") || format!("{e:#}").contains("parsing"),
        "{e:#}"
    );
    fs::remove_dir_all(&d).ok();
}

#[test]
fn missing_weights_are_reported_by_name() {
    let d = minimal_artifacts("noweights");
    fs::remove_file(d.join("weights/fc2_q.npy")).unwrap();
    let e = ArtifactStore::load(&d).unwrap_err();
    assert!(format!("{e:#}").contains("fc2_q"), "{e:#}");
    fs::remove_dir_all(&d).ok();
}

#[test]
fn label_image_count_mismatch_is_rejected() {
    let d = minimal_artifacts("mismatch");
    let labels = NpyArray {
        dtype: openacm::util::npy::DType::I64,
        shape: vec![3],
        data: vec![0u8; 24],
    };
    npy::write(&d.join("dataset/test_labels.npy"), &labels).unwrap();
    let e = ArtifactStore::load(&d).unwrap_err();
    assert!(format!("{e:#}").contains("labels"), "{e:#}");
    fs::remove_dir_all(&d).ok();
}

#[test]
fn garbage_hlo_fails_at_compile_not_load() {
    // The store only checks presence; the runtime must surface a parse
    // error with the file path in context.
    let d = minimal_artifacts("badhlo");
    let s = ArtifactStore::load(&d).unwrap();
    let rt = openacm::runtime::Runtime::cpu().unwrap();
    let e = match rt.compile_hlo_text(&s.model_hlo) {
        Err(e) => e,
        Ok(_) => panic!("garbage HLO must not compile"),
    };
    assert!(format!("{e:#}").contains("model.hlo"), "{e:#}");
    fs::remove_dir_all(&d).ok();
}

#[test]
fn corrupt_weight_dtype_is_rejected_by_weight_literals() {
    let d = minimal_artifacts("baddtype");
    // biases written as i32 instead of f32 → weight_literals accepts i32
    // (it is a legal operand type) but the QuantCnn loader must reject it.
    npy::write(
        &d.join("weights/conv1_b.npy"),
        &NpyArray::from_i32(&[8], &vec![0i32; 8]),
    )
    .unwrap();
    let e = openacm::nn::model::QuantCnn::load(Path::new(&d)).unwrap_err();
    assert!(format!("{e:#}").contains("f32"), "{e:#}");
    fs::remove_dir_all(&d).ok();
}
