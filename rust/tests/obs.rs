//! Integration tests for the `obs::` telemetry spine: concurrent
//! recording correctness, histogram percentile accuracy against an
//! exact-sorted reference, snapshot JSON round-trips, and event-log
//! routing. All registry tests run on *private* `MetricsRegistry`
//! instances (not the process-wide one) so they stay independent of
//! whatever other tests in this binary record.

use std::sync::Arc;

use openacm::obs::{Event, HistogramSnapshot, MetricsRegistry, RegistrySnapshot, Severity};
use openacm::util::stats::percentile;

/// N threads hammer M counters + histograms concurrently; the merged
/// snapshot must equal the serial sums exactly (sharded atomics lose
/// nothing).
#[test]
fn concurrent_recording_matches_serial_sums() {
    const THREADS: usize = 8;
    const METRICS: usize = 5;
    const PER_THREAD: u64 = 10_000;

    let reg = Arc::new(MetricsRegistry::new());
    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let reg = Arc::clone(&reg);
            scope.spawn(move || {
                for m in 0..METRICS {
                    let c = reg.counter(&format!("c{m}"));
                    let h = reg.histogram(&format!("h{m}"));
                    for i in 0..PER_THREAD {
                        c.add(m as u64 + 1);
                        h.record(i % 1000 + t as u64);
                    }
                }
            });
        }
    });
    let snap = reg.snapshot();
    for m in 0..METRICS {
        assert_eq!(
            snap.counters[&format!("c{m}")],
            THREADS as u64 * PER_THREAD * (m as u64 + 1),
            "counter c{m} lost increments under contention"
        );
        let h = &snap.histograms[&format!("h{m}")];
        assert_eq!(h.count, THREADS as u64 * PER_THREAD);
        let serial_sum: u64 = (0..THREADS as u64)
            .map(|t| (0..PER_THREAD).map(|i| i % 1000 + t).sum::<u64>())
            .sum();
        assert_eq!(h.sum, serial_sum, "histogram h{m} sum drifted");
        assert_eq!(h.min, 0);
        assert_eq!(h.max, 999 + THREADS as u64 - 1);
    }
}

/// Log-bucketed percentiles vs the exact sorted reference
/// (`util::stats::percentile`): the bucket design (4 sub-buckets per
/// octave) bounds relative error at ~12.5% at bucket midpoints; allow a
/// modest margin on top for quantile interpolation differences.
#[test]
fn histogram_percentiles_track_exact_sorted_reference() {
    let reg = MetricsRegistry::new();
    let h = reg.histogram("lat");
    // Deterministic log-uniform-ish samples spanning ~5 decades — the
    // shape of real latency data (xorshift, seeded).
    let mut state = 0x9E37_79B9_7F4A_7C15u64;
    let mut samples: Vec<f64> = Vec::with_capacity(2000);
    for _ in 0..2000 {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        let exp = (state >> 60) as u32; // 0..16
        let v = 10 + (state % 1000) * (1u64 << exp);
        h.record(v);
        samples.push(v as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let snap = reg.snapshot();
    let hs = &snap.histograms["lat"];
    assert_eq!(hs.count, 2000);
    for p in [10.0, 50.0, 90.0, 99.0] {
        let exact = percentile(&samples, p);
        let approx = hs.percentile(p) as f64;
        let rel = (approx - exact).abs() / exact;
        assert!(
            rel <= 0.15,
            "p{p}: approx {approx} vs exact {exact} ({:.1}% off, want <= 15%)",
            rel * 100.0
        );
    }
    // Extremes stay inside the observed range (bucket midpoints are
    // clamped to [min, max]) and within one bucket width of the true ends.
    let (lo, hi) = (samples[0], samples[samples.len() - 1]);
    let p0 = hs.percentile(0.0) as f64;
    let p100 = hs.percentile(100.0) as f64;
    assert!((lo..=lo * 1.15).contains(&p0), "p0 {p0} vs min {lo}");
    assert!((hi * 0.85..=hi).contains(&p100), "p100 {p100} vs max {hi}");
    // Mean is exact (sum and count are exact).
    let exact_mean = samples.iter().sum::<f64>() / samples.len() as f64;
    assert!((hs.mean() - exact_mean).abs() < 1e-6);
}

/// Snapshot → JSON → snapshot is the identity, including u64::MAX-scale
/// counters (numbers are kept as raw strings in the parser).
#[test]
fn snapshot_json_roundtrip_preserves_extremes() {
    let reg = MetricsRegistry::new();
    reg.counter("huge").add(u64::MAX - 1);
    reg.gauge("negative").set(i64::MIN + 1);
    let h = reg.histogram("spread");
    h.record(0);
    h.record(1);
    h.record(u64::MAX);
    let snap = reg.snapshot();
    let back = RegistrySnapshot::from_json(&snap.to_json()).unwrap();
    assert_eq!(back.counters["huge"], u64::MAX - 1);
    assert_eq!(back.gauges["negative"], i64::MIN + 1);
    assert_eq!(back.histograms["spread"].max, u64::MAX);
    assert_eq!(snap.to_json(), back.to_json());
}

/// merge is commutative-with-diff: (a merged b).diff(a) == b for
/// counters and histogram counts.
#[test]
fn merge_then_diff_recovers_the_increment() {
    let mk = |n: u64| {
        let reg = MetricsRegistry::new();
        reg.counter("c").add(n);
        let h = reg.histogram("h");
        for i in 0..n {
            h.record(i);
        }
        reg.snapshot()
    };
    let a = mk(100);
    let b = mk(42);
    let mut merged = a.clone();
    merged.merge(&b);
    let d = merged.diff(&a);
    assert_eq!(d.counters["c"], 42);
    assert_eq!(d.histograms["h"].count, 42);
}

/// HistogramSnapshot::diff subtracts bucket-wise; percentiles of the
/// difference reflect only the later interval's samples.
#[test]
fn histogram_diff_isolates_the_interval() {
    let reg = MetricsRegistry::new();
    let h = reg.histogram("x");
    for _ in 0..100 {
        h.record(10);
    }
    let early = reg.snapshot().histograms["x"].clone();
    for _ in 0..100 {
        h.record(100_000);
    }
    let late = reg.snapshot().histograms["x"].clone();
    let d: HistogramSnapshot = late.diff(&early);
    assert_eq!(d.count, 100);
    // Every sample in the interval was 100_000; p50 must land in its
    // bucket (within the ~12.5% bucket width), nowhere near 10.
    let p50 = d.percentile(50.0);
    assert!(p50 > 80_000, "diff p50 {p50} should reflect only late samples");
}

/// Events route into the in-process ring with fields intact; JSONL
/// serialization is parseable by the bundled JSON reader.
#[test]
fn event_log_records_and_serializes() {
    openacm::obs::event::set_stderr_mirror(false);
    openacm::obs::emit(
        Severity::Info,
        "obs-test",
        "hello from the test",
        &[("k", "v".to_string()), ("n", "7".to_string())],
    );
    let recent: Vec<Event> = openacm::obs::recent(64);
    let ev = recent
        .iter()
        .rev()
        .find(|e| e.subsystem == "obs-test")
        .expect("emitted event must be in the ring");
    assert_eq!(ev.message, "hello from the test");
    assert_eq!(ev.fields, vec![("k".to_string(), "v".to_string()), ("n".into(), "7".into())]);
    let line = ev.to_jsonl();
    let parsed = openacm::obs::json::parse(&line).unwrap();
    assert_eq!(parsed.get("subsystem").and_then(|j| j.as_str()), Some("obs-test"));
    assert_eq!(parsed.get("severity").and_then(|j| j.as_str()), Some("info"));
    openacm::obs::event::set_stderr_mirror(true);
}

/// The process-global registry serves one shared handle per name: two
/// lookups add into the same underlying metric.
#[test]
fn global_registry_handles_alias_by_name() {
    let a = openacm::obs::counter("obs_test.alias_check");
    let b = openacm::obs::counter("obs_test.alias_check");
    a.add(3);
    b.add(4);
    assert!(openacm::obs::counter("obs_test.alias_check").value() >= 7);
}

// ---------------------------------------------------------------------------
// CLI exit codes + follow mode
// ---------------------------------------------------------------------------

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "openacm_obs_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// `obs diff` is scriptable like `diff(1)`: identical snapshots exit 0,
/// any counter/histogram movement exits 1 (while the report still reaches
/// stdout — the exit path must flush).
#[test]
fn obs_diff_exit_code_flags_nonempty_diffs() {
    use std::process::Command;
    let dir = temp_dir("diff");
    let reg = MetricsRegistry::new();
    reg.counter("c").add(5);
    std::fs::write(dir.join("a.json"), reg.snapshot().to_json()).unwrap();
    reg.counter("c").add(3);
    reg.histogram("h").record(10);
    std::fs::write(dir.join("b.json"), reg.snapshot().to_json()).unwrap();
    let run = |a: &str, b: &str| {
        Command::new(env!("CARGO_BIN_EXE_openacm"))
            .args(["obs", "diff"])
            .arg(dir.join(a))
            .arg(dir.join(b))
            .env("OPENACM_OBS", &dir)
            .output()
            .expect("spawn openacm obs diff")
    };
    let same = run("a.json", "a.json");
    assert!(
        same.status.success(),
        "self-diff must exit 0: {:?}",
        same.status
    );
    let moved = run("a.json", "b.json");
    assert_eq!(
        moved.status.code(),
        Some(1),
        "non-empty diff must exit 1: {}",
        String::from_utf8_lossy(&moved.stderr)
    );
    let report = String::from_utf8_lossy(&moved.stdout);
    assert!(
        report.contains("telemetry diff"),
        "diff report must reach stdout before the non-zero exit: {report}"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

/// `obs tail --follow --max-polls K` drains the existing tail, follows
/// briefly, and terminates — the bounded mode scripts and CI rely on.
#[test]
fn obs_tail_follow_terminates_at_max_polls() {
    use std::process::Command;
    let dir = temp_dir("tail");
    std::fs::write(
        dir.join("events.jsonl"),
        "{\"ts_ms\":1,\"severity\":\"info\",\"subsystem\":\"t\",\
         \"message\":\"hello follow\",\"fields\":{}}\n",
    )
    .unwrap();
    let out = Command::new(env!("CARGO_BIN_EXE_openacm"))
        .args(["obs", "tail", "--follow", "--interval-ms", "5", "--max-polls", "3", "--dir"])
        .arg(&dir)
        .output()
        .expect("spawn openacm obs tail");
    assert!(out.status.success(), "{:?}", out.status);
    assert!(
        String::from_utf8_lossy(&out.stdout).contains("hello follow"),
        "tail must print the existing line before following"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

/// `follow_jsonl` streams only *complete* appended lines, never replays
/// the pre-existing tail, and restarts from the head when the file
/// shrinks underneath it (event-log rotation).
#[test]
fn follow_jsonl_streams_appends_and_survives_rotation() {
    use std::io::Write as _;
    use std::sync::Mutex;
    use std::time::{Duration, Instant};
    let dir = temp_dir("follow");
    let path = dir.join("events.jsonl");
    std::fs::write(&path, "old\n").unwrap();

    let got = Arc::new(Mutex::new(Vec::<String>::new()));
    let sink = Arc::clone(&got);
    let follow_path = path.clone();
    // Bounded follower in the background; detached — it ends on its own
    // after max_polls, and the assertions below are what the test is for.
    std::thread::spawn(move || {
        openacm::obs::cli::follow_jsonl(
            &follow_path,
            Duration::from_millis(1),
            Some(30_000),
            &mut |line| sink.lock().unwrap().push(line.to_string()),
        )
        .unwrap();
    });
    let wait_for = |want: &str| {
        let deadline = Instant::now() + Duration::from_secs(20);
        loop {
            if got.lock().unwrap().iter().any(|l| l == want) {
                return;
            }
            assert!(
                Instant::now() < deadline,
                "timed out waiting for {want:?}; got {:?}",
                got.lock().unwrap()
            );
            std::thread::sleep(Duration::from_millis(2));
        }
    };

    // A complete line plus a torn partial append: only the complete line
    // may stream; the partial must wait for its newline.
    let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
    f.write_all(b"two\nthr").unwrap();
    f.flush().unwrap();
    wait_for("two");
    assert!(
        !got.lock().unwrap().iter().any(|l| l.starts_with("thr")),
        "partial line without its newline must not be delivered"
    );
    f.write_all(b"ee\n").unwrap();
    drop(f);
    wait_for("three");

    // Rotation: the file is replaced by a shorter fresh one; the follower
    // must reset its offset to the head and stream the new content.
    std::fs::write(&path, "four\n").unwrap();
    wait_for("four");
    assert!(
        !got.lock().unwrap().iter().any(|l| l == "old"),
        "the pre-existing tail must never replay"
    );
}
