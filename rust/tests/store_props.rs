//! Design-point store correctness under stress: concurrent read/write over
//! a shared key space (no lost or torn records), and corruption of on-disk
//! records (truncation, bit flips) falling back to recompute — never
//! returning garbage.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use openacm::store::{
    DesignPointRecord, DesignPointStore, ErrorStats, Key128, KeyBuilder, PpaSummary,
};

fn scratch(tag: &str) -> PathBuf {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos();
    std::env::temp_dir().join(format!(
        "openacm_store_props_{tag}_{}_{nanos}",
        std::process::id()
    ))
}

/// The canonical record for key index `i` — fully derived from `i`, so any
/// reader can validate that what it got back is exactly what some writer
/// put (detecting cross-key mixups, truncation and torn merges).
fn record_for(i: u64) -> DesignPointRecord {
    DesignPointRecord {
        family: format!("prop_family_{i}"),
        bits: (i % 16) as u32 + 2,
        rows: 16,
        n_ops: 1000 + i,
        seed: i.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        error: Some(ErrorStats {
            nmed: i as f64 * 1.25e-4,
            mred: i as f64 * 3.5e-3,
            error_rate: (i % 100) as f64 / 100.0,
            wce: i * i,
            normalized_bias: -(i as f64) * 1e-5,
            samples: 1 << (i % 20),
        }),
        ppa: Some(PpaSummary {
            delay_ns: 5.0 + i as f64,
            logic_area_um2: 100.0 * i as f64,
            sram_area_um2: 50.0 * i as f64,
            pnr_area_um2: 150.0 * i as f64,
            power_w: 1e-4 / (i + 1) as f64,
            energy_per_op_j: 1e-12 * i as f64,
            logic_power_w: 0.5e-4,
            mult_gates: 400 + i,
        }),
        ..Default::default()
    }
}

fn key_for(i: u64) -> Key128 {
    KeyBuilder::new("props/1").u64(i).finish()
}

#[test]
fn concurrent_read_write_no_lost_or_torn_records() {
    let dir = scratch("concurrent");
    let store = DesignPointStore::open(&dir).unwrap();
    const KEYS: u64 = 16;
    const THREADS: u64 = 8;
    const OPS: u64 = 120;
    let validated = AtomicU64::new(0);
    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let store = &store;
            let validated = &validated;
            scope.spawn(move || {
                for op in 0..OPS {
                    // Walk the shared key space in a thread-dependent
                    // order so writers and readers constantly collide.
                    let i = (op.wrapping_mul(t + 1) + t) % KEYS;
                    let key = key_for(i);
                    if (op + t) % 3 == 0 {
                        store.put(key, &record_for(i)).unwrap();
                    } else if let Some(rec) = store.get(key) {
                        // Whatever a reader observes must be EXACTLY the
                        // canonical record for this key — a torn or mixed
                        // record would differ (or fail decode → None).
                        assert_eq!(rec, record_for(i), "torn/lost record for key {i}");
                        validated.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    assert!(
        validated.load(Ordering::Relaxed) > 0,
        "stress run never observed a stored record"
    );
    // Steady state: every key that was ever written reads back intact.
    let mut present = 0;
    for i in 0..KEYS {
        if let Some(rec) = store.get(key_for(i)) {
            assert_eq!(rec, record_for(i));
            present += 1;
        }
    }
    assert!(present > 0);
    let s = store.stats();
    assert_eq!(s.corrupt, 0, "no record may ever decode corrupt");
    assert_eq!(s.records, present);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn truncated_record_falls_back_to_recompute() {
    let dir = scratch("truncate");
    let store = DesignPointStore::open(&dir).unwrap();
    let key = key_for(7);
    store.put(key, &record_for(7)).unwrap();
    let path = store.path_for(key);
    // Truncate to a prefix — simulates a torn write that bypassed the
    // atomic-rename protocol (e.g. power loss on a non-journaling fs).
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    assert!(store.get(key).is_none(), "truncated record must be a miss");
    let s = store.stats();
    assert_eq!(s.corrupt, 1);
    // The fallback path: get_or_put_with recomputes and re-persists.
    let (rec, hit) = store.get_or_put_with(key, || record_for(7));
    assert!(!hit);
    assert_eq!(rec, record_for(7));
    assert_eq!(store.get(key).unwrap(), record_for(7));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn bit_flips_anywhere_fall_back_to_recompute() {
    let dir = scratch("bitflip");
    let store = DesignPointStore::open(&dir).unwrap();
    let key = key_for(3);
    let original = record_for(3);
    let clean = {
        store.put(key, &original).unwrap();
        std::fs::read(store.path_for(key)).unwrap()
    };
    // Flip one bit at a spread of positions covering header, payload and
    // checksum footer; every single one must be detected.
    for byte in (0..clean.len()).step_by(11) {
        let mut corrupted = clean.clone();
        corrupted[byte] ^= 0x10;
        std::fs::write(store.path_for(key), &corrupted).unwrap();
        if let Some(got) = store.get(key) {
            panic!(
                "bit flip at byte {byte} went undetected (got {:?})",
                got.family
            );
        }
        // Recompute restores a good record (get removed the bad file).
        let (rec, hit) = store.get_or_put_with(key, || original.clone());
        assert!(!hit);
        assert_eq!(rec, original);
    }
    assert!(store.stats().corrupt as usize >= clean.len() / 11);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn gc_and_verify_interplay_preserves_survivors() {
    let dir = scratch("gc_verify");
    let store = DesignPointStore::open(&dir).unwrap();
    for i in 0..12 {
        store.put(key_for(i), &record_for(i)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    let total = store.stats().bytes;
    let evicted = store.gc(total / 2);
    assert!(evicted > 0 && evicted < 12);
    let report = store.verify(false);
    assert_eq!(report.checked, 12 - evicted);
    assert_eq!(report.ok, report.checked);
    assert!(report.corrupt.is_empty());
    // Survivors are the newest records, still bit-exact.
    for i in evicted..12 {
        assert_eq!(store.get(key_for(i)).unwrap(), record_for(i));
    }
    std::fs::remove_dir_all(&dir).unwrap();
}
