//! Cross-language consistency: the Python build path
//! (`python/compile/mults.py`) and the Rust multiplier library must produce
//! bit-identical product LUTs — otherwise the application-level results
//! (Tables III/IV) and the AOT graph would silently diverge from the
//! hardware the compiler generates.
//!
//! Requires `make artifacts` (skips with a message when absent, so plain
//! `cargo test` works in a fresh checkout).

use std::path::Path;

use openacm::config::spec::MultFamily;
use openacm::mult::behavioral::{int8_lut, paper_families};
use openacm::util::npy;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("luts").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ missing — run `make artifacts`");
        None
    }
}

#[test]
#[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
fn python_and_rust_luts_are_bit_identical() {
    let Some(dir) = artifacts_dir() else { return };
    for (name, family) in paper_families() {
        let path = dir.join(format!("luts/lut_{name}.npy"));
        let (shape, py_lut) = npy::read_i32(&path).expect("reading python lut");
        assert_eq!(shape, vec![256, 256], "{name} shape");
        let rust_lut = int8_lut(&family);
        let mismatches: Vec<usize> = (0..65536)
            .filter(|&i| py_lut[i] != rust_lut[i])
            .take(5)
            .collect();
        assert!(
            mismatches.is_empty(),
            "{name}: {} mismatches, first at {:?} (py={}, rust={})",
            (0..65536).filter(|&i| py_lut[i] != rust_lut[i]).count(),
            mismatches.first(),
            py_lut[mismatches[0]],
            rust_lut[mismatches[0]],
        );
    }
}

#[test]
#[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
fn lut_error_statistics_match_behavioral_models() {
    let Some(dir) = artifacts_dir() else { return };
    // NMED computed from the python LUT must match the rust exhaustive
    // error metrics (they are the same table, but this guards the
    // sign-magnitude indexing convention end to end).
    let (_, lut) = npy::read_i32(&dir.join("luts/lut_logour.npy")).unwrap();
    let mut abs_sum = 0f64;
    for a in 0..256i64 {
        for b in 0..256i64 {
            let sa = if a >= 128 { a - 256 } else { a };
            let sb = if b >= 128 { b - 256 } else { b };
            let got = lut[(a as usize) << 8 | b as usize] as i64;
            abs_sum += (got - sa * sb).abs() as f64;
        }
    }
    let nmed_lut = abs_sum / 65536.0 / (127.0 * 127.0);
    let rust =
        openacm::mult::error_metrics::exhaustive(&MultFamily::LogOur, 8).nmed;
    // Same family, unsigned-domain NMED vs signed-domain: same order of
    // magnitude and within 2x (the signed table includes |a|=128).
    assert!(
        (nmed_lut / rust) > 0.4 && (nmed_lut / rust) < 2.5,
        "lut {nmed_lut} vs rust {rust}"
    );
}

#[test]
fn quantized_weights_load_into_rust_model() {
    let Some(dir) = artifacts_dir() else { return };
    let cnn = openacm::nn::model::QuantCnn::load(dir).expect("loading weights");
    assert_eq!(cnn.conv1.w_q.len(), 9 * 8);
    assert_eq!(cnn.conv2.w_q.len(), 72 * 16);
    assert_eq!(cnn.fc1.w_q.len(), 64 * 32);
    assert_eq!(cnn.fc2.w_q.len(), 32 * 10);
    assert!(cnn.conv1.in_scale > 0.0 && cnn.conv1.w_scale > 0.0);
    // Weights are genuine int8 values.
    assert!(cnn.fc2.w_q.iter().all(|&w| (-127..=127).contains(&(w as i64))));
}
