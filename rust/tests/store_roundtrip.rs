//! Acceptance: two identical `dse::sweep` runs through the store produce
//! bit-identical Pareto output, with the second run served ≥ 90% from
//! disk; the coordinator warm-starts its serving tables from the same
//! records.

use std::path::PathBuf;

use openacm::coordinator::{profile_for_variant, warm_start_profiles};
use openacm::dse::pareto::pareto_front;
use openacm::dse::sweep_configs_cached;
use openacm::store::DesignPointStore;

fn scratch(tag: &str) -> PathBuf {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .as_nanos();
    std::env::temp_dir().join(format!(
        "openacm_store_roundtrip_{tag}_{}_{nanos}",
        std::process::id()
    ))
}

const ROWS: usize = 16;
const BITS: usize = 6;
const N_OPS: usize = 200;

#[test]
fn repeated_sweep_is_bit_identical_and_served_from_store() {
    let dir = scratch("sweep");
    let store = DesignPointStore::open(&dir).unwrap();

    let cold = sweep_configs_cached(ROWS, BITS, N_OPS, 2, Some(&store));
    let after_cold = store.stats();
    assert!(after_cold.writes > 0, "cold sweep must populate the store");
    assert!(after_cold.misses > 0);

    let warm = sweep_configs_cached(ROWS, BITS, N_OPS, 2, Some(&store));
    let warm_stats = store.stats().since(&after_cold);

    // ≥ 90% of the second run's lookups served from the store (acceptance
    // criterion; in practice it is 100%).
    assert!(warm_stats.lookups() > 0);
    assert!(
        warm_stats.hit_rate() >= 0.9,
        "warm sweep hit rate {:.0}% < 90% ({} hits / {} misses)",
        warm_stats.hit_rate() * 100.0,
        warm_stats.hits,
        warm_stats.misses
    );

    // Bit-identical points: every float compares by bit pattern, not
    // tolerance.
    assert_eq!(cold.len(), warm.len());
    for (c, w) in cold.iter().zip(&warm) {
        assert_eq!(c.label, w.label);
        assert_eq!(c.family, w.family);
        assert_eq!(c.nmed.to_bits(), w.nmed.to_bits(), "{}", c.label);
        assert_eq!(
            c.energy_per_op_j.to_bits(),
            w.energy_per_op_j.to_bits(),
            "{}",
            c.label
        );
        assert_eq!(
            c.logic_area_um2.to_bits(),
            w.logic_area_um2.to_bits(),
            "{}",
            c.label
        );
        assert_eq!(c.energy_ratio.to_bits(), w.energy_ratio.to_bits(), "{}", c.label);
    }

    // ...and therefore bit-identical Pareto output.
    let front_cold: Vec<(String, u64, u64)> = pareto_front(&cold)
        .iter()
        .map(|p| (p.label.clone(), p.nmed.to_bits(), p.energy_per_op_j.to_bits()))
        .collect();
    let front_warm: Vec<(String, u64, u64)> = pareto_front(&warm)
        .iter()
        .map(|p| (p.label.clone(), p.nmed.to_bits(), p.energy_per_op_j.to_bits()))
        .collect();
    assert_eq!(front_cold, front_warm);

    // The cached path matches the uncached reference exactly.
    let reference = sweep_configs_cached(ROWS, BITS, N_OPS, 2, None);
    for (c, r) in cold.iter().zip(&reference) {
        assert_eq!(c.label, r.label);
        assert_eq!(c.nmed.to_bits(), r.nmed.to_bits());
        assert_eq!(c.energy_per_op_j.to_bits(), r.energy_per_op_j.to_bits());
    }

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn sweep_survives_store_reopen_and_warm_starts_coordinator_tables() {
    let dir = scratch("reopen");
    {
        let store = DesignPointStore::open(&dir).unwrap();
        let _ = sweep_configs_cached(ROWS, BITS, N_OPS, 2, Some(&store));
    }
    // A brand-new process (fresh index, same directory) is still warm.
    let store = DesignPointStore::open(&dir).unwrap();
    let before = store.stats();
    assert!(before.records > 0, "records must persist across reopen");
    let _ = sweep_configs_cached(ROWS, BITS, N_OPS, 2, Some(&store));
    let delta = store.stats().since(&before);
    assert!(
        delta.hit_rate() >= 0.9,
        "reopened store hit rate {:.0}%",
        delta.hit_rate() * 100.0
    );

    // Coordinator warm-start: the serving tables come straight from the
    // records the sweep just persisted.
    let profiles = warm_start_profiles(&store, BITS as u32);
    assert!(!profiles.is_empty());
    let exact = profile_for_variant(&profiles, "exact").expect("exact profile");
    assert!(exact.energy_per_op_j.is_some(), "PPA flowed into profile");
    assert!(exact.records >= 1);
    let logour = profile_for_variant(&profiles, "logour").expect("log-our profile");
    assert_eq!(logour.family, "log-our");
    assert!(
        logour.nmed.is_some(),
        "error metrics flowed into the log-our profile"
    );
    assert!(logour.nmed.unwrap() > 0.0);
    // A width filter that matches nothing yields no profiles.
    assert!(warm_start_profiles(&store, 31).is_empty());

    std::fs::remove_dir_all(&dir).unwrap();
}
