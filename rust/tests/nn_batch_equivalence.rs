//! Bit-exactness of the batched inference path against the scalar
//! reference, across every paper multiplier family, batch sizes
//! {1, 3, 32, 64}, thread counts, and GEMM shapes that are deliberately
//! not multiples of the kernel tiles.
//!
//! These are the invariants the serving stack leans on: a batched
//! response must be THE SAME BITS the per-image evaluator would have
//! produced, so accuracy sweeps, the soak suite and the coordinator can
//! use `forward_batch` interchangeably with `forward`.

use openacm::config::spec::MultFamily;
use openacm::mult::behavioral::{int8_lut, paper_families};
use openacm::nn::model::{synthetic_images, QuantCnn};
use openacm::nn::quant::{
    lut_exceeds_blocked_bound, lut_matmul, lut_matmul_acc_with, lut_matmul_batched,
    lut_matmul_batched_with,
};
use openacm::util::rng::Pcg32;
use openacm::util::simd::available_levels;

#[test]
fn forward_batch_bit_identical_to_forward_for_every_family() {
    let cnn = QuantCnn::random(5);
    for (name, family) in paper_families() {
        let lut = int8_lut(&family);
        for &bsz in &[1usize, 3, 32, 64] {
            let images = synthetic_images(bsz, 0xBA7C + bsz as u64);
            let views: Vec<&[u8]> = images.chunks(256).collect();
            let reference: Vec<Vec<f32>> = views.iter().map(|v| cnn.forward(&lut, v)).collect();
            for &threads in &[1usize, 3] {
                let batched = cnn.forward_batch(&lut, &views, threads);
                assert_eq!(batched.len(), bsz);
                for (i, row) in batched.iter().enumerate() {
                    assert_eq!(
                        row.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                        reference[i].iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                        "family {name} batch {bsz} threads {threads} image {i}"
                    );
                }
            }
        }
    }
}

#[test]
fn blocked_gemm_bit_identical_across_non_tile_multiple_shapes() {
    // TILE_M = 32, TILE_K = 128, TILE_N = 64 — every shape here straddles
    // at least one tile boundary or stays strictly inside one.
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 5, 2),
        (31, 9, 8),
        (33, 129, 17),
        (40, 200, 65),
        (196, 72, 16),
        (64, 128, 64), // exact tile multiples too
    ];
    for (lut_name, family) in [
        ("exact", MultFamily::Exact),
        ("logour", MultFamily::LogOur),
    ] {
        let lut = int8_lut(&family);
        let mut rng = Pcg32::new(99);
        for &(m, k, n) in shapes {
            // Full int8 range including -128 to stress the LUT indexing.
            let a: Vec<i8> = (0..m * k)
                .map(|_| (rng.below(256) as i64 - 128) as i8)
                .collect();
            let b: Vec<i8> = (0..k * n)
                .map(|_| (rng.below(256) as i64 - 128) as i8)
                .collect();
            let reference = lut_matmul(&lut, &a, &b, m, k, n, 0.03, 0.07);
            for threads in [1usize, 4] {
                let fast = lut_matmul_batched(&lut, &a, &b, m, k, n, 0.03, 0.07, threads);
                assert_eq!(
                    fast.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    reference.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "{lut_name} {m}x{k}x{n} threads {threads}"
                );
            }
        }
    }
}

#[test]
fn blocked_gemm_zero_heavy_rows_match_reference() {
    // Post-ReLU activations are zero-heavy; the kernel's zero-row skip
    // must be a pure no-op semantically for LUTs whose zero row is zero
    // (exact) AND stay disabled for LUTs where it is not.
    let lut = int8_lut(&MultFamily::Exact);
    let mut rng = Pcg32::new(7);
    let (m, k, n) = (50, 70, 12);
    let a: Vec<i8> = (0..m * k)
        .map(|_| {
            if rng.below(2) == 0 {
                0
            } else {
                (rng.below(255) as i64 - 127) as i8
            }
        })
        .collect();
    let b: Vec<i8> = (0..k * n)
        .map(|_| (rng.below(255) as i64 - 127) as i8)
        .collect();
    let reference = lut_matmul(&lut, &a, &b, m, k, n, 0.01, 0.02);
    let fast = lut_matmul_batched(&lut, &a, &b, m, k, n, 0.01, 0.02, 2);
    assert_eq!(fast, reference);
}

#[test]
fn every_simd_level_bit_identical_across_families_and_odd_shapes() {
    // The SIMD half of the GEMM proof obligation (DESIGN.md §"SIMD
    // kernels"): each runnable dispatch level must reproduce the scalar
    // oracle bit for bit on shapes straddling every tile boundary, for
    // every paper multiplier family.
    let levels = available_levels();
    if levels.len() == 1 {
        println!(
            "note: only the scalar level is runnable here (no AVX2/NEON, or \
             OPENACM_FORCE_SCALAR) — vector dispatch paths not exercised"
        );
    } else {
        println!(
            "SIMD levels under test: {:?}",
            levels.iter().map(|l| l.name()).collect::<Vec<_>>()
        );
    }
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 5, 2),     // strictly inside one tile
        (31, 9, 8),    // m one short of TILE_M
        (33, 129, 17), // m/k one past TILE_M/TILE_K, ragged n
        (40, 200, 65), // n one past TILE_N
        (64, 128, 64), // exact tile multiples
    ];
    for (name, family) in paper_families() {
        let lut = int8_lut(&family);
        let mut rng = Pcg32::new(0x51D0 ^ name.len() as u64);
        for &(m, k, n) in shapes {
            let a: Vec<i8> = (0..m * k)
                .map(|_| (rng.below(256) as i64 - 128) as i8)
                .collect();
            let b: Vec<i8> = (0..k * n)
                .map(|_| (rng.below(256) as i64 - 128) as i8)
                .collect();
            let oracle = lut_matmul(&lut, &a, &b, m, k, n, 0.04, 0.06);
            let oracle_bits: Vec<u32> = oracle.iter().map(|x| x.to_bits()).collect();
            for &level in &levels {
                for threads in [1usize, 3] {
                    let fast = lut_matmul_batched_with(
                        level, &lut, &a, &b, m, k, n, 0.04, 0.06, threads,
                    );
                    assert_eq!(
                        fast.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                        oracle_bits,
                        "family {name} level {} {m}x{k}x{n} threads {threads}",
                        level.name()
                    );
                }
            }
        }
    }
}

#[test]
fn maximal_magnitude_lut_is_exact_at_every_level() {
    // Regression for the overflow bugfix: entries at ±(i32 extremes) used
    // to silently wrap a k-tile's i32 partial sum in release builds (the
    // bound was only debug-asserted). The kernel must now detect the LUT
    // and produce the exact i64 result at every dispatch level.
    let mut lut = vec![0i32; 65536];
    for a in -128i32..=127 {
        for b in -128i32..=127 {
            lut[(((a as u8) as usize) << 8) | ((b as u8) as usize)] =
                if (a ^ b) < 0 { i32::MIN + 1 } else { i32::MAX };
        }
    }
    assert!(lut_exceeds_blocked_bound(&lut));
    // Strictly positive b keeps every LUT hit at ±i32::MAX exactly, so
    // each accumulator is (#pos − #neg)·i32::MAX = 186·i32::MAX — far past
    // i32 — and a k-tile's i32 partial sum really would wrap.
    let (m, k, n) = (4, 310, 7);
    let a: Vec<i8> = (0..m * k).map(|i| if i % 5 == 0 { -128 } else { 127 }).collect();
    let b: Vec<i8> = (0..k * n).map(|i| (i % 126 + 1) as i8).collect();
    for &level in &available_levels() {
        let acc = lut_matmul_acc_with(level, &lut, &a, &b, m, k, n, 2);
        for i in 0..m {
            for j in 0..n {
                let want: i64 = (0..k)
                    .map(|p| {
                        let ai = (a[i * k + p] as u8 as usize) << 8;
                        lut[ai | (b[p * n + j] as u8 as usize)] as i64
                    })
                    .sum();
                assert!(want.abs() > i32::MAX as i64, "test must exceed i32 ({i},{j})");
                assert_eq!(acc[i * n + j], want, "level {} ({i},{j})", level.name());
            }
        }
    }
}

#[test]
fn forward_batch_rows_independent_of_batchmates() {
    // The same image must produce the same bits no matter what else is in
    // the batch (the "no padding leakage" serving invariant).
    let cnn = QuantCnn::random(13);
    let lut = int8_lut(&MultFamily::Mitchell);
    let images = synthetic_images(9, 77);
    let views: Vec<&[u8]> = images.chunks(256).collect();
    let solo = cnn.forward_batch(&lut, &views[4..5], 1);
    let full = cnn.forward_batch(&lut, &views, 2);
    assert_eq!(solo[0], full[4]);
}
