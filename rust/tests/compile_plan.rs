//! The compile pass end to end: heterogeneous per-layer LUT dispatch
//! bit-exactness, plan artifact round-trips through the serving stack,
//! and the acceptance criteria of the accuracy-budgeted search (within
//! budget, strict energy improvement, store-warm recompiles).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use openacm::compile::plan::{CompiledPlan, LayerPlan, PlanLuts};
use openacm::compile::search::{compile_budgeted, CalibrationSet, CompileOptions};
use openacm::config::spec::MultFamily;
use openacm::mult::behavioral::{int8_lut, paper_families};
use openacm::nn::model::{
    layer_macs_per_image, synthetic_images, LayerLuts, QuantCnn, IMG, LAYER_NAMES, N_LAYERS,
};
use openacm::runtime::NativeFactory;
use openacm::store::DesignPointStore;

fn scratch(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "openacm_compile_it_{tag}_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ))
}

/// A plan whose every layer runs `family` (energies are placeholders —
/// these tests exercise execution, not the search).
fn uniform_plan(family: &MultFamily) -> CompiledPlan {
    let macs = layer_macs_per_image();
    CompiledPlan {
        name: format!("uniform_{}", family.name()),
        bits: 8,
        budget_drop: 0.0,
        model_hash: 0,
        calib_hash: 0,
        calib_n: 0,
        exact_top1: 1.0,
        plan_top1: 1.0,
        exact_energy_per_image_j: 1.0,
        plan_energy_per_image_j: 1.0,
        layers: (0..N_LAYERS)
            .map(|l| LayerPlan {
                layer: LAYER_NAMES[l].to_string(),
                family: family.clone(),
                energy_per_op_j: 1e-12,
                macs_per_image: macs[l],
                solo_drop: 0.0,
            })
            .collect(),
    }
}

/// Satellite: per-layer LUT dispatch with a uniform assignment must be
/// bit-identical to the model "rebuilt" with that single uniform config
/// (the classic single-LUT path), across all paper families × batch
/// {1, 32}.
#[test]
#[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
fn hetero_dispatch_matches_uniform_rebuild_all_families() {
    let cnn = QuantCnn::random(0xD15);
    for (name, family) in paper_families() {
        let lut = int8_lut(&family);
        let plan_luts = uniform_plan(&family).build_luts();
        for batch in [1usize, 32] {
            let images = synthetic_images(batch, 0xBA7C4 ^ batch as u64);
            let views: Vec<&[u8]> = images.chunks(IMG * IMG).collect();
            for threads in [1usize, 4] {
                let uniform = cnn.forward_batch(&lut, &views, threads);
                let hetero =
                    cnn.forward_batch_hetero(&plan_luts.layer_luts(), &views, threads);
                assert_eq!(
                    uniform, hetero,
                    "family {name}, batch {batch}, threads {threads}"
                );
            }
            // Scalar oracle agrees too.
            let hetero1 = cnn.forward_batch_hetero(&plan_luts.layer_luts(), &views, 1);
            for (i, v) in views.iter().enumerate() {
                assert_eq!(hetero1[i], cnn.forward(&lut, v), "family {name}, image {i}");
            }
        }
    }
}

/// A genuinely mixed assignment served through the native backend must
/// bit-match a direct heterogeneous forward, and the plan artifact must
/// survive a disk round-trip on the way.
#[test]
fn mixed_plan_roundtrips_through_native_serving() {
    let dir = scratch("serve");
    std::fs::create_dir_all(&dir).unwrap();
    let macs = layer_macs_per_image();
    let families = [
        MultFamily::Exact,
        MultFamily::default_approx(8),
        MultFamily::LogOur,
        MultFamily::Exact,
    ];
    let plan = CompiledPlan {
        name: "mixed".into(),
        bits: 8,
        budget_drop: 0.02,
        model_hash: 7,
        calib_hash: 8,
        calib_n: 32,
        exact_top1: 1.0,
        plan_top1: 0.96875,
        exact_energy_per_image_j: 2.0e-7,
        plan_energy_per_image_j: 1.5e-7,
        layers: (0..N_LAYERS)
            .map(|l| LayerPlan {
                layer: LAYER_NAMES[l].to_string(),
                family: families[l].clone(),
                energy_per_op_j: 2e-12,
                macs_per_image: macs[l],
                solo_drop: 0.0,
            })
            .collect(),
    };
    let path = dir.join("mixed.acmplan");
    plan.save(&path).unwrap();
    let loaded = CompiledPlan::load(&path).unwrap();
    assert_eq!(loaded, plan);

    let cnn = QuantCnn::random(0x5E12E);
    let mut luts = BTreeMap::new();
    luts.insert("exact".to_string(), int8_lut(&MultFamily::Exact));
    let mut factory = NativeFactory::new(cnn, luts, 8, 1);
    factory.add_plan("plan", &loaded);

    let images = synthetic_images(5, 3);
    let views: Vec<&[u8]> = images.chunks(IMG * IMG).collect();
    let mut be = factory.create("plan").unwrap();
    let served = be.infer_batch(&views).unwrap();

    // Direct heterogeneous forward with independently built LUTs.
    let direct_luts: Vec<Vec<i32>> = families.iter().map(int8_lut).collect();
    let direct = factory.model().forward_batch_hetero(
        &LayerLuts {
            conv1: &direct_luts[0],
            conv2: &direct_luts[1],
            fc1: &direct_luts[2],
            fc2: &direct_luts[3],
        },
        &views,
        2,
    );
    assert_eq!(served, direct, "served logits must bit-match direct forward");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Acceptance: a budgeted compile lands within budget with strictly
/// better energy than all-exact, its plan round-trips through the native
/// backend bit-exactly, and a second compile with the same inputs is
/// store-warm.
#[test]
#[cfg_attr(debug_assertions, ignore = "expensive: run with --release (make test)")]
fn budgeted_compile_is_within_budget_warm_and_servable() {
    let dir = scratch("accept");
    let _ = std::fs::remove_dir_all(&dir);
    let store = DesignPointStore::open(&dir).unwrap();
    let model = QuantCnn::random(0xACCE97);
    let opts = CompileOptions {
        budget_drop: 0.05,
        calib_n: 128,
        ppa_ops: 300,
        threads: 4,
        ..CompileOptions::new(0.05)
    };
    let calib = CalibrationSet::synthetic(&model, opts.calib_n, opts.seed, opts.threads);

    let plan = compile_budgeted(&model, &calib, &opts, Some(&store));

    // Within budget, by real measurement.
    assert!(
        plan.drop_vs_exact() <= opts.budget_drop + 1e-9,
        "drop {} exceeds budget {}",
        plan.drop_vs_exact(),
        opts.budget_drop
    );
    // Synthetic labels are the exact predictions, so the baseline is 1.0.
    assert_eq!(plan.exact_top1, 1.0);
    // Strictly better energy than the all-exact plan.
    assert!(
        plan.plan_energy_per_image_j < plan.exact_energy_per_image_j,
        "plan energy {} not below exact {}",
        plan.plan_energy_per_image_j,
        plan.exact_energy_per_image_j
    );
    assert!(plan.layers.iter().any(|l| l.family != MultFamily::Exact));

    // Round-trip through the serving stack: NativeBackend logits
    // bit-match a direct heterogeneous forward_batch.
    let plan_luts = plan.build_luts();
    let mut luts = BTreeMap::new();
    luts.insert("exact".to_string(), int8_lut(&MultFamily::Exact));
    let mut factory = NativeFactory::new(model.clone(), luts, 16, 2);
    factory.add_plan("plan", &plan);
    let images = synthetic_images(16, 0xF00D);
    let views: Vec<&[u8]> = images.chunks(IMG * IMG).collect();
    let mut be = factory.create("plan").unwrap();
    let served = be.infer_batch(&views).unwrap();
    let direct = model.forward_batch_hetero(&plan_luts.layer_luts(), &views, 1);
    assert_eq!(served, direct);

    // Second compile with identical inputs: bit-identical plan, ≥90% of
    // store lookups served warm.
    let before = store.stats();
    let again = compile_budgeted(&model, &calib, &opts, Some(&store));
    let delta = store.stats().since(&before);
    assert_eq!(again, plan, "warm recompile must replay bit-identically");
    assert!(
        delta.hit_rate() >= 0.9,
        "recompile only {:.0}% warm ({} hits / {} misses)",
        delta.hit_rate() * 100.0,
        delta.hits,
        delta.misses
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Uniform PlanLuts share one table; a plan over four distinct families
/// builds four distinct tables.
#[test]
fn plan_lut_sharing() {
    let u = PlanLuts::uniform(Arc::new(vec![0i32; 65536]));
    for l in 1..N_LAYERS {
        assert!(Arc::ptr_eq(&u.layers[0], &u.layers[l]));
    }
    let plan = uniform_plan(&MultFamily::Mitchell);
    let luts = plan.build_luts();
    for l in 1..N_LAYERS {
        assert!(Arc::ptr_eq(&luts.layers[0], &luts.layers[l]));
    }
}
