//! Seeded chaos harness for the fault-tolerance + elasticity layer
//! ([`openacm::coordinator::resilience`]): every scenario drives a live
//! sharded server over a deterministic [`FaultPlan`] and checks the hard
//! invariants the resilience layer must never trade away:
//!
//! * **exact accounting** — every admitted request gets exactly one
//!   [`Delivery`]; `ok + failed == admitted` under every plan;
//! * **bit-identical deliveries** — a delivered `Ok` always bit-matches
//!   the pure reference [`fixture_logits`] of (serving variant, image),
//!   fault plan or not: retries, respawns and hedges never corrupt data;
//! * **zero duplicate deliveries** — hedged duplicates are discarded
//!   internally; a client channel sees at most one message;
//! * **recovery to steady state** — once a one-shot fault window is
//!   exhausted the pipeline returns to full-throughput fault-free
//!   serving (self-healed executors, re-closed breakers).
//!
//! Scenarios: transient-burst retry recovery; panic-storm self-healing;
//! restart-budget exhaustion escalating to [`Health`]; latency/skew
//! bit-exactness; breaker ejection → degraded re-route → re-close;
//! hedging exactly-once; randomized seeded plans; a resilient soak with
//! a pre/post-fault throughput comparison and constant metrics memory.

use std::collections::{BTreeMap, HashMap};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use openacm::coordinator::batcher::BatchPolicy;
use openacm::coordinator::router::AccuracyClass;
use openacm::coordinator::server::{
    Delivery, InferenceServer, Request, Route, ServerConfig, SubmitError,
};
use openacm::coordinator::warmstart::VariantProfile;
use openacm::coordinator::{AutoscalePolicy, BreakerPolicy, ResilienceConfig};
use openacm::runtime::{
    fixture_logits, FaultPlan, FixtureFactory, LatencySpike, PanicStorm, SlowShard, TransientBursts,
};
use openacm::util::rng::Pcg32;

/// Deterministic 256-byte payload pool; the high bit (and the byte-keyed
/// injection values 0xEE/0xDD) never appear, so the only faults in play
/// are the ones the seeded plan schedules.
fn images(n: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Pcg32::new(seed);
    (0..n)
        .map(|_| (0..256).map(|_| (rng.next_u64() & 0x7f) as u8).collect())
        .collect()
}

fn bits(logits: &[f32]) -> Vec<u32> {
    logits.iter().map(|x| x.to_bits()).collect()
}

/// An SLO no healthy request will miss: chaos scenarios prove recovery
/// and accounting, not deadline behavior (covered in serving_shard.rs).
fn lax_policy(max_batch: usize) -> BatchPolicy {
    BatchPolicy {
        max_batch,
        max_wait: Duration::from_millis(1),
        slo: Duration::from_secs(60),
        ..BatchPolicy::default()
    }
}

/// Stand up a resilient server over the fixture menu + fault plan.
fn chaos_server(
    menu: &[&str],
    plan: FaultPlan,
    shards: usize,
    max_batch: usize,
    queue_limit: usize,
    res: ResilienceConfig,
) -> InferenceServer {
    InferenceServer::start_resilient(
        Arc::new(FixtureFactory::new(menu, max_batch).with_fault_plan(plan)),
        ServerConfig {
            shards,
            policy: lax_policy(max_batch),
            queue_limit,
        },
        res,
    )
    .expect("chaos server boots")
}

/// Submit under maximum pressure, rebuilding and retrying the request
/// while the server sheds (the pipeline keeps draining, so admission
/// capacity always frees up; any other error is a test failure).
fn submit_retrying(server: &InferenceServer, make: impl Fn() -> Request) {
    let mut spins = 0u64;
    loop {
        match server.submit(make()) {
            Ok(()) => return,
            Err(SubmitError::Shed { .. }) => {
                spins += 1;
                assert!(spins < 50_000_000, "submit retry loop stuck on shed");
                std::thread::yield_now();
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Transient bursts: retries absorb them completely
// ---------------------------------------------------------------------------

#[test]
fn transient_burst_is_absorbed_by_retries() {
    // One-shot burst of 3 failing calls starting at call 5; 4 retries
    // give every batch up to 5 attempts — more than the burst length.
    let plan = FaultPlan {
        seed: 0xB00,
        transient: Some(TransientBursts {
            start: 5,
            len: 3,
            period: 0,
        }),
        ..FaultPlan::default()
    };
    let res = ResilienceConfig {
        retries: 4,
        retry_backoff: Duration::from_micros(100),
        ..ResilienceConfig::default()
    };
    let recovered_before = openacm::obs::counter("serve.retry.recovered").value();
    let server = chaos_server(&["exact"], plan, 1, 1, 64, res);
    for img in images(30, 0x7A1) {
        let r = server
            .infer(img.clone(), "exact")
            .expect("retries must absorb the transient burst");
        assert_eq!(
            bits(&r.logits),
            bits(&fixture_logits("exact", &img)),
            "retried delivery must stay bit-identical"
        );
    }
    assert!(server.healthy(), "transient faults never mark unhealthy");
    let recovered = openacm::obs::counter("serve.retry.recovered").value() - recovered_before;
    assert!(recovered >= 1, "at least one batch must recover via retry");
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Panic storm: executor self-healing under a restart budget
// ---------------------------------------------------------------------------

#[test]
fn panic_storm_respawns_executor_and_keeps_delivering() {
    let plan = FaultPlan {
        seed: 0xB01,
        panic_storm: Some(PanicStorm {
            start: 5,
            panics: 2,
        }),
        ..FaultPlan::default()
    };
    let res = ResilienceConfig {
        retries: 4,
        respawn_budget: 6,
        respawn_min_interval: Duration::ZERO,
        ..ResilienceConfig::default()
    };
    let respawns_before = openacm::obs::counter("serve.executor.respawns").value();
    let server = chaos_server(&["exact"], plan, 1, 1, 64, res);
    for img in images(30, 0x7A2) {
        let r = server
            .infer(img.clone(), "exact")
            .expect("the respawned executor must keep serving");
        assert_eq!(bits(&r.logits), bits(&fixture_logits("exact", &img)));
    }
    assert!(
        server.healthy(),
        "respawns within budget must not escalate to Health: {:?}",
        server.failure()
    );
    let respawns = openacm::obs::counter("serve.executor.respawns").value() - respawns_before;
    assert!(respawns >= 2, "both storm panics respawn (saw {respawns})");
    server.shutdown();
}

#[test]
fn respawn_budget_exhaustion_escalates_to_health() {
    // A storm longer than the budget: 2 respawns are granted, the third
    // panic poisons the worker and reports through `Health` so `openacm
    // serve` exits non-zero. Admitted requests still each get exactly
    // one delivery (fail-fast after poisoning).
    let plan = FaultPlan {
        seed: 0xB02,
        panic_storm: Some(PanicStorm {
            start: 2,
            panics: 50,
        }),
        ..FaultPlan::default()
    };
    let res = ResilienceConfig {
        respawn_budget: 2,
        respawn_min_interval: Duration::ZERO,
        ..ResilienceConfig::default()
    };
    let server = chaos_server(&["exact"], plan, 1, 1, 64, res);
    let (mut ok, mut failed) = (0usize, 0usize);
    for img in images(8, 0x7A3) {
        match server.infer(img, "exact") {
            Ok(_) => ok += 1,
            Err(e) => {
                assert!(
                    e.to_string().contains("worker panicked"),
                    "failure must carry the panic reason, got: {e:#}"
                );
                failed += 1;
            }
        }
    }
    // Calls 0 and 1 precede the storm; every later blocking request
    // fails (each infer returned exactly once — the accounting identity
    // for this serialized drive).
    assert_eq!((ok, failed), (2, 6));
    assert!(!server.healthy(), "an exhausted budget must be fatal");
    let why = server.failure().expect("health must carry the reason");
    assert!(
        why.contains("restart budget exhausted"),
        "failure must name the exhausted budget, got: {why}"
    );
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Latency spikes + one slow shard: skew never corrupts data
// ---------------------------------------------------------------------------

#[test]
fn latency_spikes_and_slow_shard_stay_bit_exact() {
    const MENU: [&str; 2] = ["appro42", "exact"];
    let plan = FaultPlan {
        seed: 0xB03,
        latency: Some(LatencySpike {
            every: 4,
            delay_us: 1_500,
        }),
        slow_shard: Some(SlowShard {
            shard: 0,
            delay_us: 800,
        }),
        ..FaultPlan::default()
    };
    let server = chaos_server(&MENU, plan, 2, 8, 256, ResilienceConfig::default());
    let imgs = images(32, 0x7A4);
    let (tx, rx) = channel();
    let mut expect: HashMap<(String, Vec<u32>), i64> = HashMap::new();
    let n = 200usize;
    for i in 0..n {
        let img = imgs[i % imgs.len()].clone();
        let variant = MENU[i % MENU.len()];
        *expect
            .entry((variant.to_string(), bits(&fixture_logits(variant, &img))))
            .or_default() += 1;
        submit_retrying(&server, || {
            Request::to_variant(imgs[i % imgs.len()].clone(), variant, tx.clone())
        });
    }
    for _ in 0..n {
        match rx.recv().expect("exactly one delivery per admitted request") {
            Delivery::Ok(resp) => {
                let k = (resp.variant.clone(), bits(&resp.logits));
                let left = expect.get_mut(&k).expect("delivery matches a submission");
                *left -= 1;
                assert!(*left >= 0, "duplicate delivery for {:?}", k.0);
            }
            Delivery::Failed(reason) => panic!("delays alone must not fail requests: {reason}"),
        }
    }
    assert!(expect.values().all(|&v| v == 0), "all submissions delivered");
    assert!(server.healthy());
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Breaker: eject the faulted variant, degrade class traffic, re-close
// ---------------------------------------------------------------------------

#[test]
fn breaker_ejects_faulted_variant_degrades_and_recloses() {
    const MENU: [&str; 2] = ["appro42", "exact"];
    // Fault only the cheap variant: 6 one-shot failures, enough to trip
    // the breaker (min 4 samples) and eat the first two probes.
    let plan = FaultPlan {
        seed: 0xB04,
        variant: Some("appro42".to_string()),
        transient: Some(TransientBursts {
            start: 0,
            len: 6,
            period: 0,
        }),
        ..FaultPlan::default()
    };
    let res = ResilienceConfig {
        breaker: Some(BreakerPolicy {
            window: 8,
            min_samples: 4,
            failure_ratio: 0.5,
            cooldown: Duration::from_millis(50),
            probes: 2,
        }),
        ..ResilienceConfig::default()
    };
    let opened_before = openacm::obs::counter("serve.breaker.opened").value();
    let reclosed_before = openacm::obs::counter("serve.breaker.reclosed").value();
    let mut server = chaos_server(&MENU, plan, 1, 1, 64, res);
    // Give class routing a measured cheap rung below the exact fallback.
    let mut profiles: BTreeMap<String, VariantProfile> = BTreeMap::new();
    profiles.insert(
        "appro42".to_string(),
        VariantProfile {
            family: "appro42[chaos]".to_string(),
            nmed: None,
            energy_per_op_j: Some(1e-12),
            logic_area_um2: None,
            calib_top1: None,
            calib_drop: Some(0.005),
            records: 1,
        },
    );
    server.attach_profiles(profiles);
    let class = AccuracyClass::new("bronze", 0.02);
    let img = images(1, 0x7A5).remove(0);

    let deadline = Instant::now() + Duration::from_secs(10);
    let mut saw_failure = false;
    let mut saw_degraded_fallback = false;
    let mut recovered = false;
    while Instant::now() < deadline {
        match server.infer_route(img.clone(), Route::Class(class.clone()), None) {
            Err(_) => saw_failure = true, // burst failures while closed
            Ok(resp) => {
                assert_eq!(bits(&resp.logits), bits(&fixture_logits(&resp.variant, &img)));
                if resp.degraded {
                    // Ladder re-route: breaker open on the cheap rung,
                    // the exact fallback carries the class.
                    assert_eq!(resp.variant, "exact");
                    saw_degraded_fallback = true;
                } else if resp.variant == "appro42" && saw_degraded_fallback {
                    // A successful undegraded response on the faulted
                    // variant after degradation = the breaker admitted a
                    // probe past the exhausted burst.
                    recovered = true;
                    break;
                }
            }
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(saw_failure, "burst must surface as failures pre-trip");
    assert!(saw_degraded_fallback, "open breaker must degrade to exact");
    assert!(recovered, "probes must reach the healed variant");
    // Keep probing until the second successful probe re-closes the
    // breaker (state gauge back to 0).
    let gauge = openacm::obs::gauge("serve.breaker.appro42.state");
    let deadline = Instant::now() + Duration::from_secs(10);
    while gauge.value() != 0 && Instant::now() < deadline {
        let _ = server.infer_route(img.clone(), Route::Class(class.clone()), None);
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(gauge.value(), 0, "breaker must re-close after recovery");
    assert!(openacm::obs::counter("serve.breaker.opened").value() > opened_before);
    assert!(openacm::obs::counter("serve.breaker.reclosed").value() > reclosed_before);
    assert!(
        server.metrics.snapshot().degraded >= 1,
        "degraded deliveries must be counted"
    );
    assert!(server.healthy());
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Hedging: first success wins, duplicates never reach the client
// ---------------------------------------------------------------------------

#[test]
fn hedged_requests_deliver_exactly_once() {
    let plan = FaultPlan {
        seed: 0xB05,
        // Shard 0 noticeably slower: hedges onto the other shard
        // genuinely race (and often win).
        slow_shard: Some(SlowShard {
            shard: 0,
            delay_us: 1_200,
        }),
        ..FaultPlan::default()
    };
    let res = ResilienceConfig {
        hedge_slack: Some(Duration::ZERO), // hedge every request
        ..ResilienceConfig::default()
    };
    let server = chaos_server(&["exact"], plan, 2, 4, 4096, res);
    let imgs = images(32, 0x7A6);
    let n = 120usize;
    let mut clients = Vec::with_capacity(n);
    for i in 0..n {
        let img = imgs[i % imgs.len()].clone();
        let (tx, rx) = channel();
        server
            .submit(Request::to_variant(img.clone(), "exact", tx))
            .expect("queue limit is far above the workload");
        clients.push((img, rx));
    }
    for (img, rx) in &clients {
        match rx
            .recv_timeout(Duration::from_secs(30))
            .expect("exactly one delivery per admitted request")
        {
            Delivery::Ok(resp) => {
                assert_eq!(
                    bits(&resp.logits),
                    bits(&fixture_logits("exact", img)),
                    "whichever copy wins, the bits are the reference bits"
                );
            }
            Delivery::Failed(reason) => panic!("hedged request failed: {reason}"),
        }
    }
    // The losing copies keep executing after their winners delivered;
    // wait for at least one to be discarded (never client-visible).
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.metrics.snapshot().hedge_discarded == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(
        server.metrics.snapshot().hedge_discarded >= 1,
        "losing hedge copies must be discarded and counted"
    );
    // Drain + join everything, then prove no channel saw a second
    // message: zero duplicate deliveries.
    server.shutdown();
    for (_, rx) in &clients {
        assert!(
            rx.try_recv().is_err(),
            "a client channel must never see a duplicate delivery"
        );
    }
}

// ---------------------------------------------------------------------------
// Randomized seeded plans: invariants hold whatever the schedule
// ---------------------------------------------------------------------------

#[test]
fn randomized_fault_plans_preserve_accounting_and_bit_exactness() {
    const MENU: [&str; 2] = ["exact", "logour"];
    for seed in [11u64, 23, 37, 41, 53] {
        let plan = FaultPlan::chaos_default(seed);
        let res = ResilienceConfig {
            retries: 2,
            retry_backoff: Duration::from_micros(100),
            respawn_budget: 4,
            respawn_min_interval: Duration::from_millis(1),
            ..ResilienceConfig::default()
        };
        let server = chaos_server(&MENU, plan, 2, 4, 128, res);
        let imgs = images(48, seed);
        let (tx, rx) = channel();
        let mut expect: HashMap<(String, Vec<u32>), i64> = HashMap::new();
        let n = 300usize;
        for i in 0..n {
            let img = imgs[i % imgs.len()].clone();
            let variant = MENU[i % MENU.len()];
            *expect
                .entry((variant.to_string(), bits(&fixture_logits(variant, &img))))
                .or_default() += 1;
            submit_retrying(&server, || {
                Request::to_variant(imgs[i % imgs.len()].clone(), variant, tx.clone())
            });
        }
        let mut ok = 0usize;
        for _ in 0..n {
            match rx.recv().expect("exactly one delivery per admitted request") {
                Delivery::Ok(resp) => {
                    ok += 1;
                    let k = (resp.variant.clone(), bits(&resp.logits));
                    let left = expect
                        .get_mut(&k)
                        .expect("delivery must match a submission");
                    *left -= 1;
                    assert!(*left >= 0, "duplicate delivery under seed {seed}");
                }
                Delivery::Failed(reason) => {
                    panic!("seed {seed}: retries+respawns must absorb chaos_default: {reason}")
                }
            }
        }
        assert_eq!(ok, n, "accounting identity under seed {seed}");
        assert!(expect.values().all(|&v| v == 0));
        // Recovery to steady state: the plan's one-shot storm is spent;
        // periodic bursts stay within the retry budget forever.
        for img in imgs.iter().take(20) {
            let r = server
                .infer(img.clone(), "exact")
                .expect("steady state after the fault window");
            assert_eq!(bits(&r.logits), bits(&fixture_logits("exact", img)));
        }
        assert!(
            server.healthy(),
            "seed {seed}: budget covers the storm: {:?}",
            server.failure()
        );
        server.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Resilient soak: recovery throughput + constant metrics memory
// ---------------------------------------------------------------------------

#[test]
fn resilient_soak_recovers_to_pre_fault_throughput() {
    // All faults are one-shot and land in the first phase: a transient
    // burst at calls 0..4 and a two-panic storm at calls 10/11 (per
    // pool). Latency spikes are periodic — identical load in both
    // phases — so phase 2 measures the healed pipeline.
    let plan = FaultPlan {
        seed: 0xB06,
        transient: Some(TransientBursts {
            start: 0,
            len: 4,
            period: 0,
        }),
        panic_storm: Some(PanicStorm {
            start: 10,
            panics: 2,
        }),
        latency: Some(LatencySpike {
            every: 16,
            delay_us: 200,
        }),
        ..FaultPlan::default()
    };
    let res = ResilienceConfig {
        retries: 2,
        retry_backoff: Duration::from_micros(100),
        respawn_budget: 4,
        respawn_min_interval: Duration::from_millis(1),
        hedge_slack: Some(Duration::from_millis(5)),
        autoscale: Some(AutoscalePolicy {
            max_workers: 2,
            ..AutoscalePolicy::default()
        }),
        ..ResilienceConfig::default()
    };
    let server = chaos_server(&["exact"], plan, 2, 8, 512, res);
    let imgs = images(64, 0x7A8);
    let bytes_before = server.metrics.resident_bytes();

    let mut phase = |n: usize, faulty: bool| -> f64 {
        let (tx, rx) = channel();
        let t0 = Instant::now();
        for i in 0..n {
            submit_retrying(&server, || {
                Request::to_variant(imgs[i % imgs.len()].clone(), "exact", tx.clone())
            });
        }
        let mut failed = 0usize;
        for _ in 0..n {
            match rx.recv().expect("exactly one delivery per admitted request") {
                // Shape check only at soak scale; bit-exactness under
                // faults is proven by the scenarios above.
                Delivery::Ok(resp) => assert_eq!(resp.logits.len(), 10),
                Delivery::Failed(_) => failed += 1,
            }
        }
        let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
        if !faulty {
            assert_eq!(failed, 0, "the healed pipeline must not fail requests");
        }
        n as f64 / elapsed
    };

    let pre = phase(1_000, true); // absorbs every one-shot fault
    let post = phase(6_000, false); // healed, spikes only
    assert!(
        post >= 0.9 * pre,
        "post-fault throughput {post:.0} rps must recover to within 10% \
         of the faulty phase's {pre:.0} rps"
    );
    assert_eq!(
        server.metrics.resident_bytes(),
        bytes_before,
        "metrics memory must not grow across the soak"
    );
    assert!(
        server.healthy(),
        "soak must end healthy: {:?}",
        server.failure()
    );
    server.shutdown();
}
