"""Model-level tests: structure mirrors, quantized-vs-float agreement, and
dataset sanity."""

import jax.numpy as jnp
import numpy as np

from compile import dataset, model, mults


def test_dataset_deterministic_and_balanced():
    x1, y1 = dataset.make_split(256, seed=5)
    x2, y2 = dataset.make_split(256, seed=5)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.shape == (256, 16, 16)
    assert x1.dtype == np.uint8
    # every class appears
    assert len(np.unique(y1)) == 10


def test_im2col_matches_naive():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 5, 5, 3)).astype(np.float32))
    cols, oh, ow = model.im2col(x)
    assert (oh, ow) == (3, 3)
    cols = np.asarray(cols)
    xn = np.asarray(x)
    for b in range(2):
        for oy in range(3):
            for ox in range(3):
                naive = []
                for ky in range(3):
                    for kx in range(3):
                        for c in range(3):
                            naive.append(xn[b, oy + ky, ox + kx, c])
                np.testing.assert_allclose(cols[b, oy * 3 + ox], naive)


def test_maxpool_floor_semantics():
    x = jnp.asarray(np.arange(2 * 5 * 5 * 1, dtype=np.float32).reshape(2, 5, 5, 1))
    p = model.maxpool2(x)
    assert p.shape == (2, 2, 2, 1)
    # top-left window max of [[0,1],[5,6]] = 6
    assert float(p[0, 0, 0, 0]) == 6.0


def test_float_forward_shapes():
    params = {k: jnp.asarray(v) for k, v in model.init_params(0).items()}
    x, _ = dataset.make_split(8, seed=1)
    logits = model.float_forward(params, jnp.asarray(x, jnp.int32))
    assert logits.shape == (8, 10)


def test_quant_forward_with_exact_lut_tracks_float():
    params_np = model.init_params(3)
    params = {k: jnp.asarray(v) for k, v in params_np.items()}
    x, _ = dataset.make_split(32, seed=2)
    xj = jnp.asarray(x, jnp.int32)
    acts = model.float_activations(params, xj)
    scales_act = [model.calibrate_scale(a) for a in acts]
    qparams, scales = model.quantize_params(params_np, scales_act)
    fwd = model.make_quant_forward(qparams, scales)
    (qlogits,) = fwd(xj, jnp.asarray(mults.int8_lut("exact").reshape(-1)))
    flogits = model.float_forward(params, xj)
    # int8 static quantization: logits track within a coarse tolerance and
    # argmax agrees on a large majority.
    q = np.asarray(qlogits)
    f = np.asarray(flogits)
    scale = np.abs(f).mean() + 1e-6
    assert np.abs(q - f).mean() / scale < 0.35
    agree = (np.argmax(q, -1) == np.argmax(f, -1)).mean()
    assert agree >= 0.75, f"argmax agreement {agree}"


def test_quant_forward_family_sensitivity():
    params_np = model.init_params(4)
    params = {k: jnp.asarray(v) for k, v in params_np.items()}
    x, _ = dataset.make_split(32, seed=3)
    xj = jnp.asarray(x, jnp.int32)
    acts = model.float_activations(params, xj)
    scales_act = [model.calibrate_scale(a) for a in acts]
    qparams, scales = model.quantize_params(params_np, scales_act)
    fwd = model.make_quant_forward(qparams, scales)
    outs = {}
    for fam in ("exact", "appro42", "logour", "lm"):
        (logits,) = fwd(xj, jnp.asarray(mults.int8_lut(fam).reshape(-1)))
        outs[fam] = np.asarray(logits)
    # families genuinely differ...
    assert not np.array_equal(outs["exact"], outs["lm"])
    # ...but the accurate ones stay close to exact
    ref_norm = np.abs(outs["exact"]).mean() + 1e-6
    d_appro = np.abs(outs["appro42"] - outs["exact"]).mean() / ref_norm
    d_lm = np.abs(outs["lm"] - outs["exact"]).mean() / ref_norm
    assert d_appro < d_lm, f"appro {d_appro} should deviate less than lm {d_lm}"
