"""Tests for the Python behavioral multiplier library (mirror of the Rust
`mult` module) — compressor truth tables, family properties, LUT
correctness, plus hypothesis sweeps."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import mults


# ---- compressors -----------------------------------------------------------


def compressor_stats(kind):
    patterns = np.arange(16)
    vals = mults.compressor_value(kind, patterns)
    truth = np.array([bin(p).count("1") for p in range(16)])
    err = vals - truth
    return {
        "er": float(np.mean(err != 0)),
        "med": float(np.mean(np.abs(err))),
        "bias": float(np.mean(err)),
        "wce": int(np.max(np.abs(err))),
    }


def test_yang1_documented_stats():
    s = compressor_stats("yang1")
    assert s["er"] == 5 / 16
    assert s["med"] == 6 / 16
    assert s["bias"] < 0
    assert s["wce"] == 2


def test_kong_and_strollo_are_high_accuracy():
    assert compressor_stats("kong")["er"] == 1 / 16
    assert compressor_stats("strollo_cm3")["er"] == 1 / 16


def test_all_compressors_exact_below_two_ones():
    for kind in ("yang1", "momeni", "ha_lee", "kong", "strollo_cm3"):
        vals = mults.compressor_value(kind, np.arange(16))
        for p in (0, 1, 2, 4, 8):
            assert vals[p] == bin(p).count("1"), f"{kind} pattern {p:04b}"


def test_unknown_compressor_raises():
    with pytest.raises(ValueError):
        mults.compressor_value("nope", np.arange(16))


# ---- pp-tree multiplier ------------------------------------------------------


def test_pptree_exact_when_no_approx_cols():
    a = np.arange(256)
    b = np.arange(256)
    prod = mults.pptree_multiply(a[:, None], b[None, :], 8)
    assert (prod == a[:, None] * b[None, :]).all()


def test_pptree_approx_bounded_error():
    a = np.arange(0, 256, 3)
    b = np.arange(0, 256, 5)
    prod = mults.pptree_multiply(a[:, None], b[None, :], 8, approx_cols=8, kind="yang1")
    err = np.abs(prod - a[:, None] * b[None, :])
    assert err.max() > 0  # it does approximate
    assert err.max() < 8 * 256  # column budget bound


@settings(max_examples=60, deadline=None)
@given(
    a=st.integers(0, 2**16 - 1),
    b=st.integers(0, 2**16 - 1),
    cols=st.integers(0, 16),
)
def test_pptree_16bit_error_bound_hypothesis(a, b, cols):
    prod = int(mults.pptree_multiply(a, b, 16, approx_cols=cols, kind="yang1"))
    err = abs(prod - a * b)
    # each approximate compressor contributes |ED| <= 2 at weight 2^w,
    # with at most ~4 compressors per column across stages
    assert err <= 16 * (2 ** max(cols, 1))


# ---- logarithmic multipliers --------------------------------------------------


def test_mitchell_underestimates_and_is_exact_on_pow2():
    a = np.arange(256)
    b = np.arange(256)
    p = mults.mitchell_multiply(a[:, None], b[None, :], 8)
    assert (p <= a[:, None] * b[None, :]).all()
    for i in range(8):
        for j in range(8):
            assert p[1 << i, 1 << j] == (1 << i) * (1 << j)


def test_logour_beats_mitchell_exhaustive():
    a = np.arange(256)
    exact = a[:, None] * a[None, :]
    lm = np.abs(mults.mitchell_multiply(a[:, None], a[None, :], 8) - exact).mean()
    lo = np.abs(mults.logour_multiply(a[:, None], a[None, :], 8) - exact).mean()
    assert lo < 0.5 * lm


def test_log_families_zero_handling():
    for f in (mults.mitchell_multiply, mults.logour_multiply):
        assert f(0, 37, 8) == 0
        assert f(255, 0, 8) == 0


@settings(max_examples=80, deadline=None)
@given(a=st.integers(1, 255), b=st.integers(1, 255))
def test_logour_compensation_no_carry_hypothesis(a, b):
    # OR-merge invariant (paper Eq. 3): comp < 2^(k1+k2)
    k1, k2 = a.bit_length() - 1, b.bit_length() - 1
    q1, q2 = a - (1 << k1), b - (1 << k2)
    big, small = max(q1, q2), min(q1, q2)
    if big == 0:
        return
    kb = big.bit_length() - 1
    roundup = kb > 0 and (big >> (kb - 1)) & 1
    comp = small << (kb + int(roundup))
    assert comp < (1 << (k1 + k2))


# ---- LUTs ---------------------------------------------------------------------


def test_exact_lut_is_true_signed_product():
    lut = mults.int8_lut("exact")
    for a in range(-128, 128, 17):
        for b in range(-128, 128, 13):
            idx = ((a & 0xFF) << 8) | (b & 0xFF)
            assert lut[idx // 256, idx % 256] == a * b


def test_luts_antisymmetric_in_sign():
    for fam in mults.FAMILIES:
        lut = mults.int8_lut(fam)
        for a in range(-127, 128, 23):
            for b in range(-127, 128, 29):
                i1 = ((a & 0xFF) << 8) | (b & 0xFF)
                i2 = (((-a) & 0xFF) << 8) | (b & 0xFF)
                assert lut[i1 // 256, i1 % 256] == -lut[i2 // 256, i2 % 256]


def test_nmed_ordering_matches_paper_table4():
    v = np.arange(256)
    exact = v[:, None] * v[None, :]
    pmax = 255 * 255

    def nmed(fam):
        p = mults.unsigned_multiply(fam, v[:, None], v[None, :], 8)
        return np.abs(p - exact).mean() / pmax

    appro, logour, lm = nmed("appro42"), nmed("logour"), nmed("lm")
    assert appro < logour < lm
