"""Pallas LUT-matmul kernel vs the pure-jnp oracle — the core L1
correctness signal. Hypothesis sweeps shapes and LUT contents."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import mults
from compile.kernels import ref
from compile.kernels.approx_matmul import BM, lut_matmul, pad_rows

RNG = np.random.default_rng(7)
LUTS = {fam: mults.int8_lut(fam).reshape(-1) for fam in mults.FAMILIES}


def rand_q(shape, rng=RNG):
    return rng.integers(-127, 128, shape).astype(np.int32)


@pytest.mark.parametrize("family", mults.FAMILIES)
def test_kernel_matches_ref_all_families(family):
    lut = jnp.asarray(LUTS[family])
    a = jnp.asarray(rand_q((64, 24)))
    b = jnp.asarray(rand_q((24, 16)))
    out = lut_matmul(a, b, lut)
    expect = ref.lut_matmul_ref(a, b, lut)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_kernel_exact_family_is_integer_matmul():
    lut = jnp.asarray(LUTS["exact"])
    a = rand_q((32, 72))
    b = rand_q((72, 10))
    out = lut_matmul(jnp.asarray(a), jnp.asarray(b), lut)
    np.testing.assert_array_equal(
        np.asarray(out), a.astype(np.int64) @ b.astype(np.int64)
    )


@settings(max_examples=20, deadline=None)
@given(
    m_blocks=st.integers(1, 4),
    k=st.integers(1, 80),
    n=st.integers(1, 33),
    seed=st.integers(0, 2**31),
)
def test_kernel_matches_ref_hypothesis_shapes(m_blocks, k, n, seed):
    rng = np.random.default_rng(seed)
    m = m_blocks * BM
    a = jnp.asarray(rand_q((m, k), rng))
    b = jnp.asarray(rand_q((k, n), rng))
    lut = jnp.asarray(LUTS["logour"])
    out = lut_matmul(a, b, lut)
    expect = ref.lut_matmul_ref(a, b, lut)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_kernel_with_arbitrary_luts(seed):
    # The kernel must be a pure gather-sum for ANY table, not just real
    # multiplier tables.
    rng = np.random.default_rng(seed)
    lut = jnp.asarray(rng.integers(-(2**15), 2**15, 65536).astype(np.int32))
    a = jnp.asarray(rand_q((BM, 7), rng))
    b = jnp.asarray(rand_q((7, 5), rng))
    out = lut_matmul(a, b, lut)
    expect = ref.lut_matmul_numpy(np.asarray(a), np.asarray(b), np.asarray(lut))
    np.testing.assert_array_equal(np.asarray(out), expect)


def test_kernel_negative_index_wrapping():
    # -128 and -1 exercise the & 0xFF masking on both operands.
    lut = jnp.asarray(LUTS["exact"])
    a = jnp.asarray(np.array([[-128, -1, 127, 0]] * BM, np.int32))
    b = jnp.asarray(np.array([[-128], [-1], [127], [-127]], np.int32))
    out = lut_matmul(a, b, lut)
    expect = np.asarray(a, np.int64) @ np.asarray(b, np.int64)
    np.testing.assert_array_equal(np.asarray(out), expect)


def test_pad_rows_roundtrip():
    x = jnp.ones((BM + 3, 4), jnp.int32)
    padded, m = pad_rows(x)
    assert m == BM + 3
    assert padded.shape[0] % BM == 0
    assert int(padded[m:].sum()) == 0
