"""Smoke tests for the build path: short training runs learn, quantization
calibrates, and the args-form quantized forward (the one that gets
AOT-lowered) is semantically identical to the closure form."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import dataset, model, mults, train


def _tiny_trained(steps=40):
    params, acc, curve = train.train(steps=steps, batch=32, log_every=20)
    return params, acc, curve


def test_short_training_reduces_loss():
    _, _, curve = _tiny_trained()
    first = curve[0][1]
    last = curve[-1][1]
    assert last < first, f"loss did not drop: {first} -> {last}"


def test_calibration_scales_positive_and_ordered():
    params, _, _ = _tiny_trained()
    scales_act = train.calibrate(params, n_cal=64)
    assert len(scales_act) == 4
    assert all(s > 0 for s in scales_act)
    qparams, scales = model.quantize_params(params, scales_act)
    assert scales.shape == (8,)
    # quantized weights are genuine int8 values
    for name in ["conv1", "conv2", "fc1", "fc2"]:
        w = qparams[f"{name}_wq"]
        assert w.dtype == np.int32
        assert np.abs(w).max() <= 127


def test_args_form_equals_closure_form():
    params, _, _ = _tiny_trained()
    scales_act = train.calibrate(params, n_cal=64)
    qparams, scales = model.quantize_params(params, scales_act)
    closure = model.make_quant_forward(qparams, scales)
    args_form = model.make_quant_forward_args(scales)
    wargs = model.weight_args(qparams)
    x, _ = dataset.make_split(32, seed=9)
    xj = jnp.asarray(x, jnp.int32)
    lut = jnp.asarray(mults.int8_lut("logour").reshape(-1))
    (a,) = closure(xj, lut)
    (b,) = args_form(xj, lut, *wargs)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lowering_produces_hlo_text_with_operand_weights():
    params, _, _ = _tiny_trained()
    scales_act = train.calibrate(params, n_cal=64)
    qparams, scales = model.quantize_params(params, scales_act)
    fwd = model.make_quant_forward_args(scales)
    wargs = model.weight_args(qparams)
    specs = [jax.ShapeDtypeStruct((32, 16, 16), jnp.int32),
             jax.ShapeDtypeStruct((65536,), jnp.int32)] + [
        jax.ShapeDtypeStruct(w.shape, w.dtype) for w in wargs
    ]
    lowered = jax.jit(fwd).lower(*specs)
    from compile.aot import to_hlo_text

    hlo = to_hlo_text(lowered)
    assert "ENTRY" in hlo
    # 10 parameters: images, lut, and the 8 weight operands.
    entry = hlo[hlo.index("ENTRY"):]
    n_params = entry.count(" parameter(")
    assert n_params == 10, f"expected 10 entry parameters, found {n_params}"
