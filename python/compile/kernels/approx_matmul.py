"""Layer-1 Pallas kernel: LUT-routed int8 matmul.

Every multiply in the quantized CNN goes through a 256×256 product table
(the approximate-multiplier emulation), so the compute hot-spot is a
*gather-accumulate matmul*:

    out[i, j] = sum_k LUT[ (a[i,k] & 0xFF) << 8 | (b[k,j] & 0xFF) ]

TPU mapping (DESIGN.md §9): the 256 KiB int32 LUT is pinned whole in VMEM
(BlockSpec with a constant index map); A is tiled over rows (the grid's
only axis) and B/K are kept resident because the CNN's K ≤ 72 and N ≤ 32.
The gather is VPU work; the K-reduction vectorizes over the (bm × N) tile.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the Rust runtime
(and any PJRT backend) can run. Correctness is pinned to ``ref.py`` by
pytest + hypothesis sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile size. All call sites pad M to a multiple of this.
BM = 32


def _kernel(a_ref, b_ref, lut_ref, o_ref):
    """One (BM, N) output tile: gather-accumulate over the full K."""
    a = a_ref[...]  # [BM, K] int32 (int8 values)
    b = b_ref[...]  # [K, N] int32
    lut = lut_ref[...]  # [65536] int32
    idx = ((a[:, :, None] & 0xFF) << 8) | (b[None, :, :] & 0xFF)  # [BM,K,N]
    prods = jnp.take(lut, idx.reshape(-1), axis=0).reshape(idx.shape)
    o_ref[...] = prods.sum(axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lut_matmul(a_q, b_q, lut, interpret: bool = True):
    """Pallas LUT matmul: a_q [M,K] int32, b_q [K,N] int32, lut [65536].

    M must be a multiple of BM (pad at the call site). Returns [M,N] int32.
    """
    m, k = a_q.shape
    k2, n = b_q.shape
    assert k == k2, f"inner dims {k} != {k2}"
    assert m % BM == 0, f"M={m} must be a multiple of {BM}"
    grid = (m // BM,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, k), lambda i: (i, 0)),  # stream A row-tiles
            pl.BlockSpec((k, n), lambda i: (0, 0)),  # B resident
            pl.BlockSpec((65536,), lambda i: (0,)),  # LUT pinned in VMEM
        ],
        out_specs=pl.BlockSpec((BM, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(a_q, b_q, lut)


def pad_rows(x, multiple: int = BM):
    """Pad the leading dim up to a multiple (zeros); returns (padded, m)."""
    m = x.shape[0]
    rem = (-m) % multiple
    if rem == 0:
        return x, m
    pad = jnp.zeros((rem,) + x.shape[1:], dtype=x.dtype)
    return jnp.concatenate([x, pad], axis=0), m
