"""Pure-jnp correctness oracle for the Pallas LUT-matmul kernel.

``lut_matmul_ref(a_q, b_q, lut)``: int8-valued (stored as int32) operands,
products routed through a 65536-entry LUT indexed by the two int8 bit
patterns, accumulated in int32. This is the semantic ground truth the L1
kernel (and the Rust-native mirror) must reproduce bit for bit.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def lut_index(a_q, b_q):
    """Index into the 65536-entry LUT from int8 *values* (as int32):
    ((a & 0xFF) << 8) | (b & 0xFF)."""
    return ((a_q & 0xFF) << 8) | (b_q & 0xFF)


def lut_matmul_ref(a_q, b_q, lut):
    """Reference LUT matmul: a_q [M,K] int32, b_q [K,N] int32,
    lut [65536] int32 → [M,N] int32."""
    idx = lut_index(a_q[:, :, None], b_q[None, :, :])  # [M,K,N]
    prods = jnp.take(lut, idx.reshape(-1), axis=0).reshape(idx.shape)
    return prods.sum(axis=1).astype(jnp.int32)


def lut_matmul_numpy(a_q: np.ndarray, b_q: np.ndarray, lut: np.ndarray) -> np.ndarray:
    """Numpy twin (no jax) for hypothesis tests against integer math."""
    idx = (((a_q[:, :, None] & 0xFF) << 8) | (b_q[None, :, :] & 0xFF)).astype(np.int64)
    return lut.astype(np.int64)[idx].sum(axis=1).astype(np.int32)


def quantize_ref(x, scale):
    """Static symmetric int8 quantization (mirror of rust nn::quant)."""
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int32)
