"""AOT build path: train → quantize → lower the quantized forward to HLO
*text* → emit the full artifact bundle the Rust runtime consumes.

    python -m compile.aot --out ../artifacts

Interchange is HLO text, NOT ``.serialize()`` — the image's xla_extension
0.5.1 rejects jax ≥ 0.5's 64-bit-id protos; the text parser reassigns ids
(see /opt/xla-example/README.md and gen_hlo.py).

Artifact layout (read by rust runtime::ArtifactStore):

    artifacts/
      model.hlo.txt           quantized CNN forward, (images i32[B,16,16],
                              lut i32[65536]) -> (logits f32[B,10],)
      manifest.txt            batch=..., versions, shapes
      training_log.txt        loss curve + float/quantized accuracies
      luts/lut_{family}.npy   int8 product tables (exact/appro42/logour/lm)
      weights/*.npy           quantized weights + scales (rust mirror)
      dataset/test_images.npy, test_labels.npy
"""

from __future__ import annotations

import argparse
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import dataset, model, mults, train

BATCH = 32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(outdir: Path, steps: int = 600, limit_test: int = 512) -> None:
    outdir.mkdir(parents=True, exist_ok=True)
    log: list[str] = []

    # 1. train + calibrate + quantize
    params, float_acc, curve = train.train(steps=steps, log_lines=log)
    scales_act = train.calibrate(params)
    qparams, scales = model.quantize_params(params, scales_act)
    train.save_weights(outdir, qparams, scales)

    # 2. LUTs for the four paper families
    luts_dir = outdir / "luts"
    luts_dir.mkdir(exist_ok=True)
    luts = {}
    for family in mults.FAMILIES:
        lut = mults.int8_lut(family)
        luts[family] = lut
        np.save(luts_dir / f"lut_{family}.npy", lut)

    # 3. dataset (test split)
    _, (xte, yte) = dataset.train_test()
    xte, yte = xte[:limit_test], yte[:limit_test]
    ds_dir = outdir / "dataset"
    ds_dir.mkdir(exist_ok=True)
    np.save(ds_dir / "test_images.npy", xte.astype(np.uint8))
    np.save(ds_dir / "test_labels.npy", yte.astype(np.int64))

    # 4. lower the quantized forward. Weights are runtime OPERANDS (large
    #    integer constants mis-execute on the xla_extension 0.5.1 runtime
    #    behind the Rust PJRT client — see model.make_quant_forward_args).
    fwd_args = model.make_quant_forward_args(scales, interpret=True)
    wargs = model.weight_args(qparams)
    img_spec = jax.ShapeDtypeStruct((BATCH, 16, 16), jnp.int32)
    lut_spec = jax.ShapeDtypeStruct((65536,), jnp.int32)
    w_specs = [jax.ShapeDtypeStruct(w.shape, w.dtype) for w in wargs]
    lowered = jax.jit(fwd_args).lower(img_spec, lut_spec, *w_specs)
    hlo = to_hlo_text(lowered)
    (outdir / "model.hlo.txt").write_text(hlo)
    log.append(f"lowered model.hlo.txt ({len(hlo)} chars, batch={BATCH})")

    # 5. quantized accuracy per family (jax-side reference for Table IV)
    jfwd = jax.jit(fwd)
    for family, lut in luts.items():
        correct = 0
        lut_j = jnp.asarray(lut.reshape(-1), jnp.int32)
        for i in range(0, xte.shape[0] - BATCH + 1, BATCH):
            (logits,) = jfwd(jnp.asarray(xte[i : i + BATCH], jnp.int32), lut_j)
            correct += int((np.argmax(np.asarray(logits), -1) == yte[i : i + BATCH]).sum())
        n = (xte.shape[0] // BATCH) * BATCH
        line = f"quantized top-1 [{family}]: {correct / n:.3f} ({n} images)"
        print(line)
        log.append(line)

    # 6. manifest + training log
    (outdir / "manifest.txt").write_text(
        "\n".join(
            [
                f"batch={BATCH}",
                f"jax={jax.__version__}",
                "graph=quant_cnn_fwd(images:i32[B,16,16], lut:i32[65536], w1,b1,w2,b2,w3,b3,w4,b4) -> (logits:f32[B,10],)",
                f"families={','.join(mults.FAMILIES)}",
                f"test_images={xte.shape[0]}",
                "",
            ]
        )
    )
    (outdir / "training_log.txt").write_text(
        "\n".join(log) + "\n\nloss curve:\n"
        + "\n".join(f"{t}\t{l:.5f}" for t, l in curve) + "\n"
    )
    print(f"artifacts written to {outdir}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=600)
    args = ap.parse_args()
    build(Path(args.out), steps=args.steps)


if __name__ == "__main__":
    main()
