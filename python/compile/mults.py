"""Behavioral models of the OpenACM multiplier library — the Python mirror
of ``rust/src/mult/behavioral.rs``.

These generate the int8 sign-magnitude product LUTs consumed by the Pallas
kernel (L1) and the JAX model (L2). A cargo integration test
(``rust/tests/cross_language.rs``) compares these tables bit-for-bit with
the Rust implementations, so the two languages can never drift apart.

All functions are vectorized over numpy arrays of unsigned operands.
"""

from __future__ import annotations

import numpy as np

# ---- approximate 4-2 compressors (truth tables over 4 input bits) --------
#
# Same designs as rust/src/mult/compressor.rs; see the table there for the
# error statistics (asserted by python/tests/test_mults.py too).


def _bits(pattern: np.ndarray, i: int) -> np.ndarray:
    return (pattern >> i) & 1


def compressor_value(kind: str, pattern: np.ndarray) -> np.ndarray:
    """Encoded output value (2*carry + sum) of an approximate compressor
    for each 4-bit input pattern in ``pattern``."""
    x1, x2, x3, x4 = (_bits(pattern, i) for i in range(4))
    if kind == "yang1":
        carry = (x1 & x2) | (x3 & x4)
        s = (x1 ^ x2) | (x3 ^ x4)
    elif kind == "momeni":
        carry = (x1 & x2) | (x3 & x4)
        s = (x1 ^ x2) ^ (x3 ^ x4)
    elif kind == "ha_lee":
        carry = (x1 & x2) | (x3 & x4) | ((x1 | x2) & (x3 | x4))
        s = (x1 ^ x2) | (x3 ^ x4)
    elif kind == "kong":
        carry = (x1 & x2) | (x3 & x4) | ((x1 | x2) & (x3 | x4))
        s = ((x1 ^ x2) ^ (x3 ^ x4)) | (x1 & x2 & x3 & x4)
    elif kind == "strollo_cm3":
        carry = (x1 & x2) | (x3 & x4) | ((x1 | x2) & (x3 | x4))
        s = (x1 ^ x2) ^ (x3 ^ x4)
    elif kind == "dual_quality":
        carry = x1 | x2
        s = x3 | x4
    else:
        raise ValueError(f"unknown compressor {kind!r}")
    return 2 * carry + s


# ---- PP-tree multipliers ---------------------------------------------------
#
# Column-level simulation of the same Dadda-style reduction the Rust
# generator performs: identical grouping rules (4 → compressor, 3 → FA,
# 2 → pass), identical approximate-column policy, so results are bit-exact
# with the gate netlists.


def pptree_multiply(a, b, bits: int, approx_cols: int = 0, kind: str | None = None):
    """Vectorized PP-tree multiply. ``a``, ``b``: uint arrays < 2**bits."""
    a = np.asarray(a, dtype=np.int64)
    b = np.asarray(b, dtype=np.int64)
    shape = np.broadcast_shapes(a.shape, b.shape)
    a = np.broadcast_to(a, shape).ravel()
    b = np.broadcast_to(b, shape).ravel()
    width = 2 * bits
    # cols[w] = list of bit-arrays of weight w
    cols: list[list[np.ndarray]] = [[] for _ in range(width)]
    for i in range(bits):
        ai = (a >> i) & 1
        for j in range(bits):
            cols[i + j].append(ai & ((b >> j) & 1))

    def reduce_once(cols):
        nxt: list[list[np.ndarray]] = [[] for _ in range(width + 1)]
        for w in range(width):
            bitsl = cols[w]
            idx = 0
            while len(bitsl) - idx >= 4:
                x1, x2, x3, x4 = bitsl[idx : idx + 4]
                idx += 4
                if kind is not None and w < approx_cols:
                    pat = x1 | (x2 << 1) | (x3 << 2) | (x4 << 3)
                    val = compressor_value(kind, pat)
                    nxt[w].append(val & 1)
                    nxt[w + 1].append(val >> 1)
                else:
                    # exact 4-2 via two FAs (cin = 0)
                    s1 = x1 ^ x2 ^ x3
                    c1 = (x1 & x2) | ((x1 ^ x2) & x3)
                    s = s1 ^ x4
                    c2 = s1 & x4
                    nxt[w].append(s)
                    nxt[w + 1].append(c1)
                    nxt[w + 1].append(c2)
            rest = bitsl[idx:]
            if len(rest) == 3:
                x1, x2, x3 = rest
                nxt[w].append(x1 ^ x2 ^ x3)
                nxt[w + 1].append((x1 & x2) | ((x1 ^ x2) & x3))
            elif len(rest) == 2:
                nxt[w].extend(rest)
            elif len(rest) == 1:
                nxt[w].append(rest[0])
        return [c for c in nxt[:width]]

    while any(len(c) > 2 for c in cols):
        cols = reduce_once(cols)

    zero = np.zeros_like(a)
    row1 = sum(((c[0] if len(c) > 0 else zero) << w) for w, c in enumerate(cols))
    row2 = sum(((c[1] if len(c) > 1 else zero) << w) for w, c in enumerate(cols))
    return ((row1 + row2) & ((1 << width) - 1)).reshape(shape)


# ---- logarithmic multipliers ----------------------------------------------


def _msb(x):
    """Position of the most significant set bit (x > 0)."""
    return np.int64(np.floor(np.log2(np.maximum(x, 1))))


def mitchell_multiply(a, b, bits: int):
    """Conventional Mitchell LM [24]: AP only, EP dropped."""
    a = np.asarray(a, dtype=np.int64)
    b = np.asarray(b, dtype=np.int64)
    k1 = _msb(a)
    k2 = _msb(b)
    q1 = a - (1 << k1)
    q2 = b - (1 << k2)
    p = (1 << (k1 + k2)) + (q1 << k2) + (q2 << k1)
    return np.where((a == 0) | (b == 0), 0, p)


def _round_pow2_exp(x):
    """Exponent of the nearest power of two (x > 0); ties round up."""
    k = _msb(x)
    below = np.where(k > 0, (x >> np.maximum(k - 1, 0)) & 1, 0)
    roundup = (k > 0) & (below == 1)
    return k + roundup.astype(np.int64)


def logour_multiply(a, b, bits: int):
    """The proposed Log-our multiplier (paper Eq. 3)."""
    a = np.asarray(a, dtype=np.int64)
    b = np.asarray(b, dtype=np.int64)
    k1 = _msb(a)
    k2 = _msb(b)
    q1 = a - (1 << k1)
    q2 = b - (1 << k2)
    big = np.maximum(q1, q2)
    small = np.minimum(q1, q2)
    comp = np.where(big > 0, small << _round_pow2_exp(np.maximum(big, 1)), 0)
    p = ((1 << (k1 + k2)) | comp) + (q1 << k2) + (q2 << k1)
    return np.where((a == 0) | (b == 0), 0, p)


# ---- family dispatch + LUTs -------------------------------------------------

FAMILIES = ("exact", "appro42", "logour", "lm")


def unsigned_multiply(family: str, a, b, bits: int = 8):
    if family == "exact":
        return np.asarray(a, dtype=np.int64) * np.asarray(b, dtype=np.int64)
    if family == "appro42":
        # paper default: yang1 on the low `bits` columns (Fig 2 red box)
        return pptree_multiply(a, b, bits, approx_cols=bits, kind="yang1")
    if family == "logour":
        return logour_multiply(a, b, bits)
    if family == "lm":
        return mitchell_multiply(a, b, bits)
    raise ValueError(f"unknown family {family!r}")


def int8_lut(family: str) -> np.ndarray:
    """(256, 256) int32 LUT indexed by the int8 *bit patterns* of (a, b);
    products computed sign-magnitude through the unsigned 8-bit family —
    bit-exact with rust `mult::behavioral::int8_lut`."""
    patterns = np.arange(256, dtype=np.int64)
    signed = np.where(patterns >= 128, patterns - 256, patterns)  # int8 value
    av = signed[:, None]
    bv = signed[None, :]
    mag = unsigned_multiply(family, np.abs(av), np.abs(bv), bits=8)
    sign = np.sign(av) * np.sign(bv)
    return (sign * mag).astype(np.int32)


def uint8_lut(family: str) -> np.ndarray:
    """(256, 256) int32 LUT over unsigned 8-bit operands (image blending)."""
    v = np.arange(256, dtype=np.int64)
    return unsigned_multiply(family, v[:, None], v[None, :], bits=8).astype(np.int32)
