"""Layer-2 JAX model: the quantized CNN whose every multiply goes through
the approximate-multiplier LUT (via the L1 Pallas kernel).

Architecture (mirrors ``rust/src/nn/model.rs`` exactly):

    input u8 [B,16,16] → /255
    conv3x3(1→8)  + bias + relu + maxpool2   (14×14 → 7×7)
    conv3x3(8→16) + bias + relu + maxpool2   (5×5  → 2×2)
    flatten(64) → fc(64→32) + relu → fc(32→10)

Convolutions are im2col + LUT-matmul; quantization is static symmetric
int8 with per-layer calibrated activation scales. The float forward
(`float_forward`) is the training-time model; `quant_forward` is what gets
AOT-lowered (weights baked as constants, image + LUT as runtime operands).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.approx_matmul import lut_matmul, pad_rows

IMG = 16
C1_OUT = 8
C2_OUT = 16
FC1_OUT = 32
CLASSES = 10


# ---- shared structure -----------------------------------------------------


def im2col(x, k=3):
    """x [B,H,W,C] → patches [B, OH*OW, k*k*C] in (ky, kx, ch) order —
    the same order as rust nn::model::im2col."""
    b, h, w, c = x.shape
    oh, ow = h - k + 1, w - k + 1
    cols = []
    for ky in range(k):
        for kx in range(k):
            cols.append(x[:, ky : ky + oh, kx : kx + ow, :])  # [B,OH,OW,C]
    # stack → [B,OH,OW,k*k,C] → [B, OH*OW, k*k*C]
    patches = jnp.stack(cols, axis=3)
    return patches.reshape(b, oh * ow, k * k * c), oh, ow


def maxpool2(x):
    """x [B,H,W,C] → [B,H//2,W//2,C] (floor, matches rust)."""
    b, h, w, c = x.shape
    oh, ow = h // 2, w // 2
    x = x[:, : 2 * oh, : 2 * ow, :]
    x = x.reshape(b, oh, 2, ow, 2, c)
    return x.max(axis=(2, 4))


def init_params(seed: int = 0):
    """He-initialized float parameters."""
    rng = np.random.default_rng(seed)

    def he(shape, fan_in):
        return rng.normal(0, np.sqrt(2.0 / fan_in), size=shape).astype(np.float32)

    return {
        "conv1_w": he((9, C1_OUT), 9),
        "conv1_b": np.zeros(C1_OUT, np.float32),
        "conv2_w": he((9 * C1_OUT, C2_OUT), 72),
        "conv2_b": np.zeros(C2_OUT, np.float32),
        "fc1_w": he((64, FC1_OUT), 64),
        "fc1_b": np.zeros(FC1_OUT, np.float32),
        "fc2_w": he((FC1_OUT, CLASSES), FC1_OUT),
        "fc2_b": np.zeros(CLASSES, np.float32),
    }


# ---- float (training) forward ----------------------------------------------


def float_forward(params, images_u8):
    """images_u8 [B,16,16] uint8/int32 → logits [B,10] (pure float)."""
    x = images_u8.astype(jnp.float32) / 255.0
    x = x[..., None]  # [B,16,16,1]
    h, oh, ow = im2col(x)
    h = h.reshape(-1, 9) @ params["conv1_w"] + params["conv1_b"]
    h = jax.nn.relu(h).reshape(-1, oh, ow, C1_OUT)
    h = maxpool2(h)
    h, oh, ow = im2col(h)
    h = h.reshape(-1, 9 * C1_OUT) @ params["conv2_w"] + params["conv2_b"]
    h = jax.nn.relu(h).reshape(-1, oh, ow, C2_OUT)
    h = maxpool2(h)
    h = h.reshape(h.shape[0], -1)  # [B,64]
    h = jax.nn.relu(h @ params["fc1_w"] + params["fc1_b"])
    return h @ params["fc2_w"] + params["fc2_b"]


# ---- intermediate activations (for calibration) -----------------------------


def float_activations(params, images_u8):
    """Returns the pre-quantization inputs of each LUT matmul."""
    x = images_u8.astype(jnp.float32) / 255.0
    x = x[..., None]
    a1, oh, ow = im2col(x)
    h = a1.reshape(-1, 9) @ params["conv1_w"] + params["conv1_b"]
    h = jax.nn.relu(h).reshape(-1, oh, ow, C1_OUT)
    h = maxpool2(h)
    a2, oh2, ow2 = im2col(h)
    h = a2.reshape(-1, 72) @ params["conv2_w"] + params["conv2_b"]
    h = jax.nn.relu(h).reshape(-1, oh2, ow2, C2_OUT)
    h = maxpool2(h)
    a3 = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(a3 @ params["fc1_w"] + params["fc1_b"])
    a4 = h
    return a1.reshape(-1, 9), a2.reshape(-1, 72), a3, a4


# ---- quantization ------------------------------------------------------------


def calibrate_scale(x) -> float:
    """max|x| / 127 (mirror of rust nn::quant::calibrate)."""
    return float(max(np.max(np.abs(np.asarray(x))), 1e-8) / 127.0)


def quantize_params(params, scales_act):
    """Quantize weights; returns (quantized dict, scales array in the
    [in1, w1, in2, w2, in3, w3, in4, w4] order rust expects)."""
    out = {}
    scales = []
    for i, name in enumerate(["conv1", "conv2", "fc1", "fc2"]):
        w = np.asarray(params[f"{name}_w"])
        ws = calibrate_scale(w)
        out[f"{name}_wq"] = np.clip(np.round(w / ws), -127, 127).astype(np.int32)
        out[f"{name}_b"] = np.asarray(params[f"{name}_b"], np.float32)
        scales.extend([float(scales_act[i]), ws])
    return out, np.asarray(scales, np.float32)


# ---- quantized forward (the AOT graph) ---------------------------------------


def _qlayer(a_f32, w_q, bias, in_scale, w_scale, lut, interpret=True):
    """One quantized layer: quantize activations, LUT-matmul, rescale."""
    a_q = ref.quantize_ref(a_f32, in_scale)
    a_q, m = pad_rows(a_q)
    acc = lut_matmul(a_q, w_q, lut, interpret=interpret)[:m]
    return acc.astype(jnp.float32) * (in_scale * w_scale) + bias


def make_quant_forward_args(scales, interpret: bool = True):
    """Quantized forward with weights as *runtime operands*:

        fn(images i32[B,16,16], lut i32[65536],
           w1 i32[9,8],  b1 f32[8],  w2 i32[72,16], b2 f32[16],
           w3 i32[64,32], b3 f32[32], w4 i32[32,10], b4 f32[10])
        → (logits f32[B,10],)

    Weights MUST be operands, not baked constants: xla_extension 0.5.1
    (the runtime behind the Rust PJRT client) mis-executes large integer
    array constants inside the pallas-interpret loops — discovered during
    bring-up and documented in EXPERIMENTS.md §Perf/debug. Only the scalar
    scales are baked into the graph.
    """
    s = [float(v) for v in scales]

    def forward(images, lut, w1, b1, w2, b2, w3, b3, w4, b4):
        b = images.shape[0]
        x = images.astype(jnp.float32) / 255.0
        x = x[..., None]
        h, oh, ow = im2col(x)
        h = _qlayer(h.reshape(-1, 9), w1, b1, s[0], s[1], lut, interpret)
        h = jax.nn.relu(h).reshape(b, oh, ow, C1_OUT)
        h = maxpool2(h)
        h, oh2, ow2 = im2col(h)
        h = _qlayer(h.reshape(-1, 72), w2, b2, s[2], s[3], lut, interpret)
        h = jax.nn.relu(h).reshape(b, oh2, ow2, C2_OUT)
        h = maxpool2(h)
        h = h.reshape(b, -1)
        h = jax.nn.relu(_qlayer(h, w3, b3, s[4], s[5], lut, interpret))
        return (_qlayer(h, w4, b4, s[6], s[7], lut, interpret),)

    return forward


def weight_args(qparams):
    """The (w1, b1, …, w4, b4) argument tuple for the args-form forward."""
    return (
        jnp.asarray(qparams["conv1_wq"], jnp.int32),
        jnp.asarray(qparams["conv1_b"]),
        jnp.asarray(qparams["conv2_wq"], jnp.int32),
        jnp.asarray(qparams["conv2_b"]),
        jnp.asarray(qparams["fc1_wq"], jnp.int32),
        jnp.asarray(qparams["fc1_b"]),
        jnp.asarray(qparams["fc2_wq"], jnp.int32),
        jnp.asarray(qparams["fc2_b"]),
    )


def make_quant_forward(qparams, scales, interpret: bool = True):
    """Convenience closure form (weights captured) used by the Python-side
    evaluations and tests: fn(images, lut) → (logits,). Semantically
    identical to the args form."""
    base = make_quant_forward_args(scales, interpret)
    wargs = weight_args(qparams)

    def forward(images, lut):
        return base(images, lut, *wargs)

    return forward


def accuracy(logits, labels):
    pred = np.argmax(np.asarray(logits), axis=-1)
    return float(np.mean(pred == np.asarray(labels)))
