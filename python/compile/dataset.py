"""Deterministic synthetic 10-class dataset (16×16 grayscale).

Substitute for ILSVRC2012 (DESIGN.md §3): each class is an oriented-grating
pattern with a class-specific (angle, frequency, waveform) signature plus
random phase, shift and noise, so a small CNN has real features to learn
while the dataset stays fully reproducible and license-free.
"""

from __future__ import annotations

import numpy as np

IMG = 16
CLASSES = 10


def make_split(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Return (images u8 [n, 16, 16], labels int64 [n])."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, CLASSES, size=n)
    yy, xx = np.mgrid[0:IMG, 0:IMG].astype(np.float64)
    images = np.zeros((n, IMG, IMG), dtype=np.uint8)
    for i in range(n):
        c = int(labels[i])
        # Classes differ only by a modest rotation of the grating (18°
        # steps) with overlapping frequencies, heavy additive noise,
        # random contrast and brightness — deliberately hard enough that
        # int8 + approximate-multiplier error visibly moves Top-1
        # (the Table IV regime).
        angle = np.pi * c / CLASSES + rng.normal(0, 0.06)
        freq = 2.0 + 0.25 * (c % 4) + rng.normal(0, 0.1)
        phase = rng.uniform(0, 2 * np.pi)
        dx, dy = rng.uniform(-3, 3, size=2)
        u = ((xx - dx) * np.cos(angle) + (yy - dy) * np.sin(angle)) / IMG
        wave = np.sin(2 * np.pi * freq * u + phase)
        if c % 3 == 2:  # double-frequency mix classes
            wave = 0.7 * wave + 0.3 * np.sin(4 * np.pi * freq * u)
        contrast = rng.uniform(28.0, 55.0)
        brightness = 127.5 + rng.normal(0, 18.0)
        img = brightness + contrast * wave
        img += rng.normal(0, 26.0, size=(IMG, IMG))
        images[i] = np.clip(img, 0, 255).astype(np.uint8)
    return images, labels.astype(np.int64)


def train_test(n_train: int = 4096, n_test: int = 512, seed: int = 2026):
    """The canonical splits used by train.py and aot.py."""
    xtr, ytr = make_split(n_train, seed)
    xte, yte = make_split(n_test, seed + 1)
    return (xtr, ytr), (xte, yte)
