"""Build-time training of the float CNN on the synthetic dataset, followed
by post-training quantization calibration.

Pure JAX (no optax in this environment): hand-rolled Adam + softmax
cross-entropy. Training runs once under ``make artifacts``; the loss curve
and final accuracies are written to ``artifacts/training_log.txt`` and the
quantized weights/scales to ``artifacts/weights/``.
"""

from __future__ import annotations

import functools
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import dataset, model


def cross_entropy(params, images, labels):
    logits = model.float_forward(params, images)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = logits[jnp.arange(labels.shape[0]), labels] - logz
    return -ll.mean()


@functools.partial(jax.jit, static_argnames=())
def adam_step(params, m, v, t, images, labels, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    loss, grads = jax.value_and_grad(cross_entropy)(params, images, labels)
    new_params, new_m, new_v = {}, {}, {}
    for k in params:
        new_m[k] = b1 * m[k] + (1 - b1) * grads[k]
        new_v[k] = b2 * v[k] + (1 - b2) * grads[k] ** 2
        mhat = new_m[k] / (1 - b1**t)
        vhat = new_v[k] / (1 - b2**t)
        new_params[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new_params, new_m, new_v, loss


def train(
    steps: int = 600,
    batch: int = 64,
    seed: int = 0,
    log_every: int = 25,
    log_lines: list[str] | None = None,
):
    """Train; returns (params, test_acc, loss_curve)."""
    (xtr, ytr), (xte, yte) = dataset.train_test()
    params = {k: jnp.asarray(v) for k, v in model.init_params(seed).items()}
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(v_) for k, v_ in params.items()}
    rng = np.random.default_rng(seed + 99)
    curve = []
    for t in range(1, steps + 1):
        idx = rng.integers(0, xtr.shape[0], size=batch)
        images = jnp.asarray(xtr[idx], jnp.int32)
        labels = jnp.asarray(ytr[idx])
        params, m, v, loss = adam_step(params, m, v, t, images, labels)
        if t % log_every == 0 or t == 1:
            curve.append((t, float(loss)))
            line = f"step {t:4d}  loss {float(loss):.4f}"
            print(line)
            if log_lines is not None:
                log_lines.append(line)
    logits = model.float_forward(params, jnp.asarray(xte, jnp.int32))
    acc = model.accuracy(logits, yte)
    line = f"float test top-1: {acc:.3f} ({xte.shape[0]} images)"
    print(line)
    if log_lines is not None:
        log_lines.append(line)
    return {k: np.asarray(v) for k, v in params.items()}, acc, curve


def calibrate(params, n_cal: int = 256) -> list[float]:
    """Activation scales from a calibration batch (train distribution)."""
    (xtr, _), _ = dataset.train_test()
    acts = model.float_activations(
        {k: jnp.asarray(v) for k, v in params.items()},
        jnp.asarray(xtr[:n_cal], jnp.int32),
    )
    return [model.calibrate_scale(a) for a in acts]


def save_weights(outdir: Path, qparams, scales):
    """Write the npy bundle rust nn::model::QuantCnn::load expects."""
    wdir = outdir / "weights"
    wdir.mkdir(parents=True, exist_ok=True)
    for name in ["conv1", "conv2", "fc1", "fc2"]:
        np.save(wdir / f"{name}_q.npy", qparams[f"{name}_wq"].astype(np.int32))
        np.save(wdir / f"{name}_b.npy", qparams[f"{name}_b"].astype(np.float32))
    np.save(wdir / "scales.npy", np.asarray(scales, np.float32))


if __name__ == "__main__":
    params, acc, _ = train()
    scales_act = calibrate(params)
    qparams, scales = model.quantize_params(params, scales_act)
    save_weights(Path("../artifacts"), qparams, scales)
    print("saved weights; float top-1", acc)
