//! Sharded serving throughput/latency bench: the adversarial workload
//! generator replayed at maximum pressure through the fixture-backed
//! coordinator, for shard counts {1, 4}.
//!
//! The fixture backend computes logits as a pure function of
//! (variant, image) in ~ns, so the measured numbers are the *pipeline's*
//! overhead — routing, admission, deadline-bucket batching, channel hops,
//! delivery — not a CNN's. Every `Ok` delivery is bit-verified against
//! [`fixture_logits`] and the accounting identity
//! `delivered == admitted` / `admitted + rejected == submitted` is
//! asserted before any number is reported.
//!
//! ```text
//! cargo bench --bench serving                 # 200k requests per config
//! OPENACM_SMOKE=1 cargo bench --bench serving # CI smoke (20k)
//! ```
//!
//! Writes `BENCH_serving.json`: per-config mean/p50/p99 latency,
//! throughput counters, the shard4_over_shard1 throughput ratio, the
//! tracing-overhead ratio, and two resilience columns — fault-burst
//! recovery (retries vs shed-only delivered counts, seeded
//! [`FaultPlan`]) and step-load elasticity (autoscaled vs fixed pool
//! under a deadline-pressuring slow backend).

use std::collections::HashSet;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use openacm::bench::harness::{BenchJson, BenchResult};
use openacm::coordinator::batcher::BatchPolicy;
use openacm::coordinator::server::{Delivery, InferenceServer, Request, ServerConfig, SubmitError};
use openacm::coordinator::{AutoscalePolicy, ResilienceConfig};
use openacm::runtime::{fixture_logits, FaultPlan, FixtureFactory, TransientBursts};
use openacm::util::proptest::{adversarial_workload, WorkloadSpec, ADVERSARIAL_PATTERNS};
use openacm::util::rng::Pcg32;

const MENU: [&str; 4] = ["appro42", "exact", "lm", "logour"];

fn images(n: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Pcg32::new(seed);
    (0..n)
        .map(|_| (0..256).map(|_| (rng.next_u64() & 0x7f) as u8).collect())
        .collect()
}

fn bits(logits: &[f32]) -> Vec<u32> {
    logits.iter().map(|x| x.to_bits()).collect()
}

struct DriveStats {
    result: BenchResult,
    admitted: u64,
    rejected: u64,
    sheds: u64,
    failed: u64,
    rps: f64,
}

/// Replay the four adversarial patterns (n/4 requests each) through a
/// `shards`-shard server at maximum pressure, retrying sheds so every
/// well-formed request transits the pipeline exactly once.
fn drive(shards: usize, n: usize) -> DriveStats {
    let imgs = images(64, 0xBE9C);
    // The reference set every delivery must bit-match.
    let valid: HashSet<(String, Vec<u32>)> = MENU
        .iter()
        .flat_map(|v| {
            imgs.iter()
                .map(move |img| (v.to_string(), bits(&fixture_logits(v, img))))
        })
        .collect();
    let server = InferenceServer::start_sharded(
        Arc::new(FixtureFactory::new(&MENU, 32)),
        ServerConfig {
            shards,
            policy: BatchPolicy {
                max_batch: 32,
                max_wait: Duration::from_micros(500),
                slo: Duration::from_millis(100),
                ..BatchPolicy::default()
            },
            queue_limit: 4096,
        },
    )
    .expect("server boots");
    let metrics = Arc::clone(&server.metrics);

    let (tx, rx) = channel();
    let drainer = std::thread::spawn(move || {
        let mut ok = 0u64;
        let mut failed = 0u64;
        while let Ok(d) = rx.recv() {
            match d {
                Delivery::Ok(resp) => {
                    assert!(
                        valid.contains(&(resp.variant.clone(), bits(&resp.logits))),
                        "delivered logits do not bit-match any (variant, image) reference"
                    );
                    ok += 1;
                }
                Delivery::Failed(_) => failed += 1,
            }
        }
        (ok, failed)
    });

    let per_pattern = (n / ADVERSARIAL_PATTERNS.len()).max(1);
    let mut admitted = 0u64;
    let mut rejected = 0u64;
    let mut sheds = 0u64;
    let t0 = Instant::now();
    for pattern in ADVERSARIAL_PATTERNS {
        let spec = WorkloadSpec {
            pattern,
            n: per_pattern,
            images: imgs.len(),
            variants: MENU.len(),
            ..WorkloadSpec::default()
        };
        for r in adversarial_workload(0x5E12 ^ shards as u64, &spec) {
            let payload = match r.malformed {
                Some(size) => vec![0u8; size],
                None => imgs[r.image].clone(),
            };
            loop {
                let req = Request::to_variant(payload.clone(), MENU[r.variant], tx.clone());
                match server.submit(req) {
                    Ok(()) => {
                        admitted += 1;
                        break;
                    }
                    Err(SubmitError::Shed { .. }) => {
                        sheds += 1;
                        std::thread::yield_now();
                    }
                    Err(SubmitError::Malformed(_)) => {
                        assert!(r.malformed.is_some(), "well-formed payload bounced");
                        rejected += 1;
                        break;
                    }
                    Err(e) => panic!("unexpected submit error: {e}"),
                }
            }
        }
    }
    drop(tx);
    let (ok, failed) = drainer.join().expect("drainer");
    let wall = t0.elapsed();

    assert_eq!(ok + failed, admitted, "exactly one delivery per admitted request");
    assert!(server.healthy(), "bench run must stay healthy");
    let snap = metrics.snapshot();
    assert_eq!(snap.completed, ok);
    assert_eq!(snap.failed, failed);
    server.shutdown();

    let rps = admitted as f64 / wall.as_secs_f64();
    DriveStats {
        result: BenchResult {
            name: format!("serve shards={shards} adversarial mix"),
            iters: admitted as usize,
            mean_ns: wall.as_nanos() as f64 / admitted as f64,
            p50_ns: snap.p50_ms * 1e6,
            p99_ns: snap.p99_ms * 1e6,
            min_ns: 0.0,
        },
        admitted,
        rejected,
        sheds,
        failed,
        rps,
    }
}

/// Drive `n` single-request batches through a 1-shard server whose
/// backend fails 2 of every 8 calls (seeded periodic transient bursts).
/// With `retries == 0` every faulted call becomes a `Failed` delivery
/// (the shed-only posture); with retries the executor absorbs the bursts
/// and delivers everything. Returns `(delivered, failed)`.
fn drive_fault_burst(n: usize, retries: u32) -> (u64, u64) {
    let imgs = images(64, 0xFA01);
    let plan = FaultPlan {
        seed: 0xFB,
        transient: Some(TransientBursts {
            start: 0,
            len: 2,
            period: 8,
        }),
        ..FaultPlan::default()
    };
    let res = ResilienceConfig {
        retries,
        retry_backoff: Duration::from_micros(50),
        ..ResilienceConfig::default()
    };
    let server = InferenceServer::start_resilient(
        Arc::new(FixtureFactory::new(&["exact"], 1).with_fault_plan(plan)),
        ServerConfig {
            shards: 1,
            policy: BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_micros(200),
                slo: Duration::from_secs(30),
                ..BatchPolicy::default()
            },
            queue_limit: 1024,
        },
        res,
    )
    .expect("fault-burst server boots");
    let valid: HashSet<Vec<u32>> = imgs
        .iter()
        .map(|img| bits(&fixture_logits("exact", img)))
        .collect();
    let (tx, rx) = channel();
    let drainer = std::thread::spawn(move || {
        let mut ok = 0u64;
        let mut failed = 0u64;
        while let Ok(d) = rx.recv() {
            match d {
                Delivery::Ok(resp) => {
                    assert!(
                        valid.contains(&bits(&resp.logits)),
                        "retried delivery does not bit-match its reference"
                    );
                    ok += 1;
                }
                Delivery::Failed(_) => failed += 1,
            }
        }
        (ok, failed)
    });
    for i in 0..n {
        loop {
            let req = Request::to_variant(imgs[i % imgs.len()].clone(), "exact", tx.clone());
            match server.submit(req) {
                Ok(()) => break,
                Err(SubmitError::Shed { .. }) => std::thread::yield_now(),
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
    }
    drop(tx);
    let (ok, failed) = drainer.join().expect("drainer");
    assert_eq!(ok + failed, n as u64, "exactly one delivery per request");
    assert!(server.healthy());
    server.shutdown();
    (ok, failed)
}

/// Step-load elasticity: max-pressure traffic against a 300 µs/call
/// backend under a 25 ms SLO. A fixed single-worker pool falls behind —
/// queued requests blow their deadline and fail — while an autoscaled
/// pool grows to `max_workers` and keeps delivering. Returns
/// `(delivered, failed)`.
fn drive_step_load(n: usize, autoscale: Option<AutoscalePolicy>) -> (u64, u64) {
    let imgs = images(64, 0xFA02);
    let plan = FaultPlan {
        seed: 0x51,
        exec_delay_us: 300,
        ..FaultPlan::default()
    };
    let res = ResilienceConfig {
        autoscale,
        ..ResilienceConfig::default()
    };
    let server = InferenceServer::start_resilient(
        Arc::new(FixtureFactory::new(&["exact"], 4).with_fault_plan(plan)),
        ServerConfig {
            shards: 1,
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_micros(200),
                slo: Duration::from_millis(25),
                ..BatchPolicy::default()
            },
            queue_limit: 512,
        },
        res,
    )
    .expect("step-load server boots");
    let (tx, rx) = channel();
    let drainer = std::thread::spawn(move || {
        let mut ok = 0u64;
        let mut failed = 0u64;
        while let Ok(d) = rx.recv() {
            match d {
                Delivery::Ok(_) => ok += 1,
                Delivery::Failed(_) => failed += 1,
            }
        }
        (ok, failed)
    });
    for i in 0..n {
        loop {
            let req = Request::to_variant(imgs[i % imgs.len()].clone(), "exact", tx.clone());
            match server.submit(req) {
                Ok(()) => break,
                Err(SubmitError::Shed { .. }) => std::thread::yield_now(),
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
    }
    drop(tx);
    let (ok, failed) = drainer.join().expect("drainer");
    assert_eq!(ok + failed, n as u64, "exactly one delivery per request");
    assert!(server.healthy());
    server.shutdown();
    (ok, failed)
}

fn main() {
    let smoke_env = std::env::var("OPENACM_SMOKE")
        .map(|v| !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false"))
        .unwrap_or(false);
    let smoke = smoke_env || std::env::args().any(|a| a == "--smoke");
    let n = if smoke { 20_000 } else { 200_000 };
    println!(
        "sharded serving bench: {n} adversarial requests per config{}",
        if smoke { " [smoke]" } else { "" }
    );

    let mut json = BenchJson::new("serving");
    let mut rps_by_shards = Vec::new();
    for shards in [1usize, 4] {
        let s = drive(shards, n);
        println!(
            "shards={shards}: {} admitted ({} malformed rejected, {} sheds retried, \
             {} failed) — {:.0} req/s, latency p50 {:.3} ms p99 {:.3} ms",
            s.admitted,
            s.rejected,
            s.sheds,
            s.failed,
            s.rps,
            s.result.p50_ns / 1e6,
            s.result.p99_ns / 1e6
        );
        json.case(&s.result);
        json.counter(&format!("shards{shards}.admitted"), s.admitted as f64);
        json.counter(&format!("shards{shards}.rejected_malformed"), s.rejected as f64);
        json.counter(&format!("shards{shards}.shed_retries"), s.sheds as f64);
        json.counter(&format!("shards{shards}.failed"), s.failed as f64);
        json.counter(&format!("shards{shards}.req_per_s"), s.rps);
        rps_by_shards.push(s.rps);
    }
    let ratio = rps_by_shards[1] / rps_by_shards[0];
    println!("→ shard scaling (4 over 1): {ratio:.2}x throughput");
    json.ratio("shard4_over_shard1", ratio);

    // Trace-context overhead: the shard-1 drive again with tracing (ids,
    // stage stamps, tail sampling) switched off. Untraced-over-traced
    // throughput ≈ 1.0 when the carried context is genuinely cheap;
    // "overhead" in the name marks the ratio lower-is-better for
    // `openacm obs regress`.
    openacm::obs::set_trace_enabled(false);
    let untraced = drive(1, n);
    openacm::obs::set_trace_enabled(true);
    let overhead = untraced.rps / rps_by_shards[0];
    println!(
        "→ tracing overhead (shard 1): {overhead:.3}x (untraced {:.0} vs traced {:.0} req/s)",
        untraced.rps, rps_by_shards[0]
    );
    json.ratio("serve_trace_overhead_shard1", overhead);

    // Fault burst: the same recoverable fault schedule, shed-only
    // (retries 0 — every faulted call is a failed delivery) vs retrying.
    // The ISSUE acceptance bar: the fault-tolerant posture delivers
    // strictly more.
    let n_fault = if smoke { 2_000 } else { 12_000 };
    let (shed_ok, shed_failed) = drive_fault_burst(n_fault, 0);
    let (res_ok, res_failed) = drive_fault_burst(n_fault, 4);
    assert!(
        shed_failed > 0,
        "the fault plan must actually fail shed-only deliveries"
    );
    assert!(
        res_ok > shed_ok,
        "retries must deliver strictly more than shed-only \
         ({res_ok} vs {shed_ok})"
    );
    let recovery = res_ok as f64 / shed_ok.max(1) as f64;
    println!(
        "fault burst ({n_fault} reqs): shed-only delivered {shed_ok} (failed {shed_failed}), \
         retries delivered {res_ok} (failed {res_failed}) — {recovery:.2}x recovery"
    );
    json.counter("fault_burst.shed_only.delivered", shed_ok as f64);
    json.counter("fault_burst.shed_only.failed", shed_failed as f64);
    json.counter("fault_burst.resilient.delivered", res_ok as f64);
    json.counter("fault_burst.resilient.failed", res_failed as f64);
    json.ratio("fault_recovery_delivered_over_shed_only", recovery);

    // Step load: a 300 µs/call backend under a 25 ms SLO. Fixed
    // single-worker pools drown (deadline expiries); the autoscaled pool
    // grows to 3 workers and keeps delivering.
    let n_step = if smoke { 4_000 } else { 20_000 };
    let scale_ups_before = openacm::obs::counter("serve.autoscale.scale_ups").value();
    let (fixed_ok, fixed_failed) = drive_step_load(n_step, None);
    let (auto_ok, auto_failed) = drive_step_load(
        n_step,
        Some(AutoscalePolicy {
            max_workers: 3,
            scale_up_wait: Duration::from_micros(500),
            scale_down_wait: Duration::from_micros(100),
            tick: Duration::from_millis(2),
        }),
    );
    assert!(
        openacm::obs::counter("serve.autoscale.scale_ups").value() > scale_ups_before,
        "step load must trigger at least one scale-up"
    );
    assert!(
        auto_ok > fixed_ok,
        "the autoscaled pool must deliver strictly more than the fixed \
         pool ({auto_ok} vs {fixed_ok})"
    );
    let elastic = auto_ok as f64 / fixed_ok.max(1) as f64;
    println!(
        "step load ({n_step} reqs): fixed delivered {fixed_ok} (failed {fixed_failed}), \
         autoscaled delivered {auto_ok} (failed {auto_failed}) — {elastic:.2}x elastic win"
    );
    json.counter("step_load.fixed.delivered", fixed_ok as f64);
    json.counter("step_load.fixed.failed", fixed_failed as f64);
    json.counter("step_load.autoscaled.delivered", auto_ok as f64);
    json.counter("step_load.autoscaled.failed", auto_failed as f64);
    json.ratio("elastic_step_delivered_over_fixed", elastic);

    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
