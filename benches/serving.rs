//! Sharded serving throughput/latency bench: the adversarial workload
//! generator replayed at maximum pressure through the fixture-backed
//! coordinator, for shard counts {1, 4}.
//!
//! The fixture backend computes logits as a pure function of
//! (variant, image) in ~ns, so the measured numbers are the *pipeline's*
//! overhead — routing, admission, deadline-bucket batching, channel hops,
//! delivery — not a CNN's. Every `Ok` delivery is bit-verified against
//! [`fixture_logits`] and the accounting identity
//! `delivered == admitted` / `admitted + rejected == submitted` is
//! asserted before any number is reported.
//!
//! ```text
//! cargo bench --bench serving                 # 200k requests per config
//! OPENACM_SMOKE=1 cargo bench --bench serving # CI smoke (20k)
//! ```
//!
//! Writes `BENCH_serving.json`: per-config mean/p50/p99 latency,
//! throughput counters, and the shard4_over_shard1 throughput ratio.

use std::collections::HashSet;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use openacm::bench::harness::{BenchJson, BenchResult};
use openacm::coordinator::batcher::BatchPolicy;
use openacm::coordinator::server::{Delivery, InferenceServer, Request, ServerConfig, SubmitError};
use openacm::runtime::{fixture_logits, FixtureFactory};
use openacm::util::proptest::{adversarial_workload, WorkloadSpec, ADVERSARIAL_PATTERNS};
use openacm::util::rng::Pcg32;

const MENU: [&str; 4] = ["appro42", "exact", "lm", "logour"];

fn images(n: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Pcg32::new(seed);
    (0..n)
        .map(|_| (0..256).map(|_| (rng.next_u64() & 0x7f) as u8).collect())
        .collect()
}

fn bits(logits: &[f32]) -> Vec<u32> {
    logits.iter().map(|x| x.to_bits()).collect()
}

struct DriveStats {
    result: BenchResult,
    admitted: u64,
    rejected: u64,
    sheds: u64,
    failed: u64,
    rps: f64,
}

/// Replay the four adversarial patterns (n/4 requests each) through a
/// `shards`-shard server at maximum pressure, retrying sheds so every
/// well-formed request transits the pipeline exactly once.
fn drive(shards: usize, n: usize) -> DriveStats {
    let imgs = images(64, 0xBE9C);
    // The reference set every delivery must bit-match.
    let valid: HashSet<(String, Vec<u32>)> = MENU
        .iter()
        .flat_map(|v| {
            imgs.iter()
                .map(move |img| (v.to_string(), bits(&fixture_logits(v, img))))
        })
        .collect();
    let server = InferenceServer::start_sharded(
        Arc::new(FixtureFactory::new(&MENU, 32)),
        ServerConfig {
            shards,
            policy: BatchPolicy {
                max_batch: 32,
                max_wait: Duration::from_micros(500),
                slo: Duration::from_millis(100),
                ..BatchPolicy::default()
            },
            queue_limit: 4096,
        },
    )
    .expect("server boots");
    let metrics = Arc::clone(&server.metrics);

    let (tx, rx) = channel();
    let drainer = std::thread::spawn(move || {
        let mut ok = 0u64;
        let mut failed = 0u64;
        while let Ok(d) = rx.recv() {
            match d {
                Delivery::Ok(resp) => {
                    assert!(
                        valid.contains(&(resp.variant.clone(), bits(&resp.logits))),
                        "delivered logits do not bit-match any (variant, image) reference"
                    );
                    ok += 1;
                }
                Delivery::Failed(_) => failed += 1,
            }
        }
        (ok, failed)
    });

    let per_pattern = (n / ADVERSARIAL_PATTERNS.len()).max(1);
    let mut admitted = 0u64;
    let mut rejected = 0u64;
    let mut sheds = 0u64;
    let t0 = Instant::now();
    for pattern in ADVERSARIAL_PATTERNS {
        let spec = WorkloadSpec {
            pattern,
            n: per_pattern,
            images: imgs.len(),
            variants: MENU.len(),
            ..WorkloadSpec::default()
        };
        for r in adversarial_workload(0x5E12 ^ shards as u64, &spec) {
            let payload = match r.malformed {
                Some(size) => vec![0u8; size],
                None => imgs[r.image].clone(),
            };
            loop {
                let req = Request::to_variant(payload.clone(), MENU[r.variant], tx.clone());
                match server.submit(req) {
                    Ok(()) => {
                        admitted += 1;
                        break;
                    }
                    Err(SubmitError::Shed { .. }) => {
                        sheds += 1;
                        std::thread::yield_now();
                    }
                    Err(SubmitError::Malformed(_)) => {
                        assert!(r.malformed.is_some(), "well-formed payload bounced");
                        rejected += 1;
                        break;
                    }
                    Err(e) => panic!("unexpected submit error: {e}"),
                }
            }
        }
    }
    drop(tx);
    let (ok, failed) = drainer.join().expect("drainer");
    let wall = t0.elapsed();

    assert_eq!(ok + failed, admitted, "exactly one delivery per admitted request");
    assert!(server.healthy(), "bench run must stay healthy");
    let snap = metrics.snapshot();
    assert_eq!(snap.completed, ok);
    assert_eq!(snap.failed, failed);
    server.shutdown();

    let rps = admitted as f64 / wall.as_secs_f64();
    DriveStats {
        result: BenchResult {
            name: format!("serve shards={shards} adversarial mix"),
            iters: admitted as usize,
            mean_ns: wall.as_nanos() as f64 / admitted as f64,
            p50_ns: snap.p50_ms * 1e6,
            p99_ns: snap.p99_ms * 1e6,
            min_ns: 0.0,
        },
        admitted,
        rejected,
        sheds,
        failed,
        rps,
    }
}

fn main() {
    let smoke_env = std::env::var("OPENACM_SMOKE")
        .map(|v| !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false"))
        .unwrap_or(false);
    let smoke = smoke_env || std::env::args().any(|a| a == "--smoke");
    let n = if smoke { 20_000 } else { 200_000 };
    println!(
        "sharded serving bench: {n} adversarial requests per config{}",
        if smoke { " [smoke]" } else { "" }
    );

    let mut json = BenchJson::new("serving");
    let mut rps_by_shards = Vec::new();
    for shards in [1usize, 4] {
        let s = drive(shards, n);
        println!(
            "shards={shards}: {} admitted ({} malformed rejected, {} sheds retried, \
             {} failed) — {:.0} req/s, latency p50 {:.3} ms p99 {:.3} ms",
            s.admitted,
            s.rejected,
            s.sheds,
            s.failed,
            s.rps,
            s.result.p50_ns / 1e6,
            s.result.p99_ns / 1e6
        );
        json.case(&s.result);
        json.counter(&format!("shards{shards}.admitted"), s.admitted as f64);
        json.counter(&format!("shards{shards}.rejected_malformed"), s.rejected as f64);
        json.counter(&format!("shards{shards}.shed_retries"), s.sheds as f64);
        json.counter(&format!("shards{shards}.failed"), s.failed as f64);
        json.counter(&format!("shards{shards}.req_per_s"), s.rps);
        rps_by_shards.push(s.rps);
    }
    let ratio = rps_by_shards[1] / rps_by_shards[0];
    println!("→ shard scaling (4 over 1): {ratio:.2}x throughput");
    json.ratio("shard4_over_shard1", ratio);

    // Trace-context overhead: the shard-1 drive again with tracing (ids,
    // stage stamps, tail sampling) switched off. Untraced-over-traced
    // throughput ≈ 1.0 when the carried context is genuinely cheap;
    // "overhead" in the name marks the ratio lower-is-better for
    // `openacm obs regress`.
    openacm::obs::set_trace_enabled(false);
    let untraced = drive(1, n);
    openacm::obs::set_trace_enabled(true);
    let overhead = untraced.rps / rps_by_shards[0];
    println!(
        "→ tracing overhead (shard 1): {overhead:.3}x (untraced {:.0} vs traced {:.0} req/s)",
        untraced.rps, rps_by_shards[0]
    );
    json.ratio("serve_trace_overhead_shard1", overhead);

    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
