//! Scalar vs batched vs blocked native inference — the serving backend's
//! headline number: the batched, cache-blocked, threadpool-parallel
//! LUT-GEMM path must beat the naive per-image scalar forward by a wide
//! margin at serving batch sizes (acceptance: ≥ 5× at batch 32).
//!
//! ```text
//! cargo bench --bench nn_forward              # full size
//! OPENACM_SMOKE=1 cargo bench --bench nn_forward   # CI smoke
//! ```
//!
//! Writes `BENCH_nn_forward.json` (per-case ns/iter + the speedup ratios)
//! for the CI artifact trail, next to `BENCH_store_warm.json`.

use openacm::bench::harness::{bench, black_box, BenchJson};
use openacm::config::spec::MultFamily;
use openacm::mult::behavioral::int8_lut;
use openacm::nn::model::{synthetic_images, QuantCnn};
use openacm::nn::quant::{lut_matmul, lut_matmul_batched, lut_matmul_batched_with};
use openacm::util::simd::{detect, SimdLevel};
use openacm::util::threadpool::ThreadPool;

fn main() {
    let smoke_env = std::env::var("OPENACM_SMOKE")
        .map(|v| !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false"))
        .unwrap_or(false);
    let smoke = smoke_env || std::env::args().any(|a| a == "--smoke");
    let threads = ThreadPool::default_parallelism();
    let iters = if smoke { 3 } else { 10 };
    let batches: &[usize] = if smoke { &[1, 32] } else { &[1, 8, 32, 64] };
    println!(
        "native inference: scalar vs batched vs blocked, {threads} threads{}",
        if smoke { " [smoke]" } else { "" }
    );

    let cnn = QuantCnn::random(42);
    let lut = int8_lut(&MultFamily::Exact);
    let mut json = BenchJson::new("nn_forward");
    let mut scalar_b32 = f64::NAN;
    let mut blocked_b32 = f64::NAN;

    for &bsz in batches {
        let images = synthetic_images(bsz, 7 + bsz as u64);
        let views: Vec<&[u8]> = images.chunks(256).collect();

        // Scalar reference: one naive triple-loop forward per image.
        let scalar = bench(&format!("forward scalar x{bsz}"), 1, iters, || {
            for v in &views {
                black_box(cnn.forward(&lut, v));
            }
        });
        json.case(&scalar);

        // Batched single-thread: batch-of-N im2col + blocked GEMM, no
        // threadpool — isolates the cache-blocking/layout win.
        let batched = bench(&format!("forward_batch x{bsz} 1thr"), 1, iters, || {
            black_box(cnn.forward_batch(&lut, &views, 1));
        });
        json.case(&batched);

        // Blocked + threadpool: the serving configuration.
        let blocked = bench(
            &format!("forward_batch x{bsz} {threads}thr"),
            1,
            iters,
            || {
                black_box(cnn.forward_batch(&lut, &views, threads));
            },
        );
        json.case(&blocked);

        if bsz == 32 {
            scalar_b32 = scalar.mean_ns;
            blocked_b32 = blocked.mean_ns;
            json.ratio("batched_1thr_over_scalar_b32", scalar.mean_ns / batched.mean_ns);
        }
    }

    let speedup = scalar_b32 / blocked_b32;
    println!("→ batched blocked speedup over per-image scalar at batch 32: {speedup:.1}x");
    json.ratio("batched_blocked_over_scalar_b32", speedup);

    // Raw GEMM: conv2's batch-32 shape (m = 32·25 rows, k = 72, n = 16) —
    // the kernel-level view of the same win.
    {
        let (m, k, n) = (32 * 25, 72, 16);
        let a: Vec<i8> = (0..m * k).map(|i| ((i * 37) % 255) as i64 as i8).collect();
        let b: Vec<i8> = (0..k * n).map(|i| ((i * 91) % 251) as i64 as i8).collect();
        let reference = bench(&format!("lut_matmul ref {m}x{k}x{n}"), 1, iters, || {
            black_box(lut_matmul(&lut, &a, &b, m, k, n, 0.02, 0.03));
        });
        json.case(&reference);
        let fast = bench(
            &format!("lut_matmul_batched {m}x{k}x{n} {threads}thr"),
            1,
            iters,
            || {
                black_box(lut_matmul_batched(&lut, &a, &b, m, k, n, 0.02, 0.03, threads));
            },
        );
        json.case(&fast);
        json.ratio("blocked_gemm_over_reference", reference.mean_ns / fast.mean_ns);

        // SIMD dispatch on the same shape, single-threaded so the column
        // isolates the vector-width win (bit-identical outputs; see
        // rust/tests/nn_batch_equivalence.rs). On scalar-only hosts (or
        // under OPENACM_FORCE_SCALAR) both columns run the same code and
        // the ratio reads ≈ 1.
        let level = detect();
        println!("→ SIMD dispatch level: {}", level.name());
        let scalar_gemm = bench(
            &format!("lut_matmul_batched {m}x{k}x{n} 1thr scalar"),
            1,
            iters,
            || {
                black_box(lut_matmul_batched_with(
                    SimdLevel::Scalar,
                    &lut,
                    &a,
                    &b,
                    m,
                    k,
                    n,
                    0.02,
                    0.03,
                    1,
                ));
            },
        );
        json.case(&scalar_gemm);
        let simd_gemm = bench(
            &format!("lut_matmul_batched {m}x{k}x{n} 1thr {}", level.name()),
            1,
            iters,
            || {
                black_box(lut_matmul_batched_with(
                    level, &lut, &a, &b, m, k, n, 0.02, 0.03, 1,
                ));
            },
        );
        json.case(&simd_gemm);
        println!(
            "→ {} GEMM speedup over scalar dispatch: {:.2}x",
            level.name(),
            scalar_gemm.mean_ns / simd_gemm.mean_ns
        );
        json.ratio("simd_gemm_over_scalar", scalar_gemm.mean_ns / simd_gemm.mean_ns);
    }

    // Observability overhead guard: the instrumented hot path (spans +
    // boundary counters, OPENACM_TRACE on) must cost ≤ 2% over the
    // untraced path on the serving-configuration forward. min_ns is the
    // noise-robust comparator (best case of each arm); the +20 µs floor
    // absorbs timer jitter on the smoke configuration.
    {
        let images = synthetic_images(32, 7 + 32);
        let views: Vec<&[u8]> = images.chunks(256).collect();
        let was_traced = openacm::obs::trace_enabled();
        openacm::obs::set_trace_enabled(false);
        let plain = bench("forward_batch x32 obs-off", 1, iters, || {
            black_box(cnn.forward_batch(&lut, &views, threads));
        });
        json.case(&plain);
        openacm::obs::set_trace_enabled(true);
        let traced = bench("forward_batch x32 obs-on", 1, iters, || {
            black_box(cnn.forward_batch(&lut, &views, threads));
        });
        json.case(&traced);
        openacm::obs::set_trace_enabled(was_traced);
        let overhead = traced.min_ns / plain.min_ns;
        println!("→ obs instrumentation overhead at batch 32: {:.2}% ", (overhead - 1.0) * 100.0);
        json.ratio("obs_overhead_b32", overhead);
        assert!(
            traced.min_ns <= plain.min_ns * 1.02 + 20_000.0,
            "obs instrumentation overhead too high: traced {:.0} ns vs plain {:.0} ns",
            traced.min_ns,
            plain.min_ns
        );
    }

    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
