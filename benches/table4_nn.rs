//! Regenerates **Table IV**: Top-1/Top-5 + NMED/MRED per multiplier family
//! on the quantized CNN (the ResNet-18/ImageNet substitute — DESIGN.md §3),
//! through BOTH execution paths (native mirror and the AOT PJRT graph),
//! and times single-batch inference.
//!
//! Requires `make artifacts`; prints a skip message otherwise.
//!
//! ```text
//! cargo bench --bench table4_nn
//! ```

use openacm::bench::harness::{bench, black_box};
use openacm::nn::cli::{eval_native, eval_pjrt, render_table4};
use openacm::runtime::{client, ArtifactStore, Runtime};

fn main() {
    let dir = ArtifactStore::default_dir();
    if !ArtifactStore::exists(&dir) {
        println!("skipping table4_nn: artifacts missing — run `make artifacts`");
        return;
    }
    let store = ArtifactStore::load(&dir).expect("artifacts");
    let limit = 512;

    println!("== native engine (rust mirror of the JAX graph) ==");
    let rows = eval_native(&store, limit).expect("native eval");
    render_table4(&rows).print();

    println!("\n== PJRT engine (AOT HLO through the runtime) ==");
    let rows = eval_pjrt(&store, limit).expect("pjrt eval");
    render_table4(&rows).print();

    println!(
        "\npaper Table IV reference (ResNet-18 / ILSVRC2012):\n\
         Exact .677/.873, Appro4-2 .668/.880 (NMED 1.70E-9), Log-our .680/.870 (4.40E-3), LM .610/.842 (2.79E-2)\n\
         shape to reproduce: Appro4-2/Log-our ~= Exact (Log-our may exceed it), LM clearly degraded.\n"
    );

    // --- hot path: one batch through PJRT ---
    let rt = Runtime::cpu().unwrap();
    let model = rt.compile_hlo_text(&store.model_hlo).unwrap();
    let b = store.batch;
    let lut = client::literal_i32(&[65536], store.luts.get("exact").unwrap()).unwrap();
    let weights = client::weight_literals(&store.weights).unwrap();
    let mut px = vec![0i32; b * 256];
    for j in 0..b {
        for (k, &p) in store.image(j).iter().enumerate() {
            px[j * 256 + k] = p as i32;
        }
    }
    let img = client::literal_i32(&[b, 16, 16], &px).unwrap();
    let r = bench(&format!("pjrt batch-{b} inference"), 2, 20, || {
        let mut args = vec![img.clone(), lut.clone()];
        args.extend(weights.iter().cloned());
        black_box(model.run_f32(&args, b * 10).unwrap());
    });
    println!(
        "→ {:.0} images/s through the AOT graph",
        r.throughput(b as f64)
    );
}
