//! Regenerates **Table II**: post-layout PPA of SRAM-multiplier systems
//! ({16×8, 32×16, 64×32} × {OpenC², Exact, Log-our, Appro4-2} at 100 MHz,
//! 0.5 pF), and times the PPA engine itself (netlist generation + activity
//! simulation + STA + power model) per configuration.
//!
//! ```text
//! cargo bench --bench table2_ppa
//! ```

use openacm::bench::harness::{bench, black_box};
use openacm::config::spec::MacroSpec;
use openacm::ppa::cli::{full_table2, render_table2};
use openacm::ppa::report::analyze_macro;
use openacm::util::threadpool::ThreadPool;

fn main() {
    // --- the table itself ---
    let rows = full_table2(2000, 0x7AB1E2, ThreadPool::default_parallelism());
    render_table2(&rows).print();
    println!(
        "\npaper Table II reference (same layout):\n\
         16x8:  OpenC2 1431/8483/2.82E-4, Exact 1079/8131/2.45E-4, Log 1173/8225/2.82E-4, Appro 939/7991/2.11E-4\n\
         32x16: OpenC2 4842/21752/1.15E-3, Exact 3568/20478/1.08E-3, Log 2402/19312/6.15E-4, Appro 2633/19543/7.58E-4\n\
         64x32: OpenC2 19734/68376/7.00E-3, Exact 10132/58774/4.03E-3, Log 4960/53602/1.45E-3, Appro 9331/57973/3.36E-3\n\
         (columns: logic um2 / P&R um2 / power W)\n"
    );

    // --- headline deltas ---
    let get = |name: &str, fam: &str| {
        rows.iter()
            .find(|r| r.name == name && r.family_label == fam)
            .unwrap()
    };
    for size in ["dcim16x8", "dcim32x16", "dcim64x32"] {
        let ex = get(size, "Exact");
        let lo = get(size, "Log-our");
        let ap = get(size, "Appro4-2");
        println!(
            "{size}: log-our logic area -{:.0}% / logic power -{:.0}%, appro4-2 logic power -{:.0}% vs exact",
            (1.0 - lo.logic_area_um2 / ex.logic_area_um2) * 100.0,
            (1.0 - lo.logic_power_w / ex.logic_power_w) * 100.0,
            (1.0 - ap.logic_power_w / ex.logic_power_w) * 100.0,
        );
    }

    // --- timing the engine hot path ---
    println!();
    let spec = MacroSpec::new("dcim16x8", 16, 8, MacroSpec::table2_families(8)[1].clone());
    bench("ppa::analyze_macro(16x8, 2000 ops)", 1, 10, || {
        black_box(analyze_macro(&spec, 2000, 1));
    });
    let spec32 = MacroSpec::new(
        "dcim64x32",
        64,
        32,
        MacroSpec::table2_families(32)[1].clone(),
    );
    bench("ppa::analyze_macro(64x32, 500 ops)", 1, 3, || {
        black_box(analyze_macro(&spec32, 500, 1));
    });
}
