//! Regenerates **Table V**: plain Monte-Carlo vs MNIS importance sampling
//! on trimmed {16, 32, 64}×2 SRAM arrays — Pf, FoM, #Sim, speedup — and
//! times the transistor-level cell characterization (the simulator each
//! method invokes).
//!
//! ```text
//! cargo bench --bench table5_yield            # full (minutes)
//! OPENACM_FAST=1 cargo bench --bench table5_yield   # reduced budgets
//! ```

use openacm::bench::harness::{bench, black_box};
use openacm::sram::cell6t::Cell6T;
use openacm::util::threadpool::ThreadPool;
use openacm::yield_analysis::cli::{run_size, table5};

fn main() {
    let fast = std::env::var("OPENACM_FAST").is_ok();
    let (fom, mc_max, mnis_max) = if fast {
        (0.10, 60_000, 20_000)
    } else {
        // FoM 0.05 is the paper's accuracy class; MC cost scales 1/FoM^2,
        // which is exactly the regime where MNIS pays off (Table V).
        (0.05, 500_000, 50_000)
    };
    let threads = ThreadPool::default_parallelism();
    let mut rows = Vec::new();
    for size in [16usize, 32, 64] {
        eprintln!("running {size}x2 (MC then MNIS, FoM target {fom})...");
        rows.push(run_size(size, fom, mc_max, mnis_max, 2026, threads));
    }
    table5(&rows).print();
    println!(
        "\npaper Table V reference:\n\
         16x2: MC 1.6E-4/0.1/55,600  MNIS 3.2E-4/0.05/2,985  → 18x\n\
         32x2: MC 6.4E-2/0.17/22,900 MNIS 1.7E-2/0.15/2,260  → 10x\n\
         64x2: MC 3.9E-3/0.05/41,500 MNIS 1.5E-3/0.03/4,260  → 9.7x\n\
         shape to reproduce: MNIS reaches the same FoM with ~an order of\n\
         magnitude fewer simulator calls on every size.\n"
    );

    // --- hot path: one transistor-level cell characterization ---
    let cell = Cell6T::default();
    bench("cell6t::characterize_read (yield hot path)", 5, 200, || {
        black_box(cell.characterize_read());
    });
    bench("cell6t::characterize (full, incl. hold SNM)", 2, 50, || {
        black_box(cell.characterize());
    });
}
