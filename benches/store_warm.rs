//! Warm-vs-cold DSE sweep through the design-point store — the store's
//! headline number: a repeated sweep must be served from disk at a wide
//! margin over recomputation, with bit-identical results.
//!
//! ```text
//! cargo bench --bench store_warm              # full size (8-bit, 1500 ops)
//! OPENACM_SMOKE=1 cargo bench --bench store_warm   # CI smoke (5-bit)
//! ```
//!
//! Writes `BENCH_store_warm.json` (per-case ns/iter + the warm_over_cold
//! ratio) for the CI artifact trail.

use openacm::bench::harness::{bench, black_box, BenchJson};
use openacm::dse::sweep_configs_cached;
use openacm::store::DesignPointStore;
use openacm::util::threadpool::ThreadPool;

fn main() {
    let smoke_env = std::env::var("OPENACM_SMOKE")
        .map(|v| !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false"))
        .unwrap_or(false);
    let smoke = smoke_env || std::env::args().any(|a| a == "--smoke");
    // Smoke mode keeps CI cheap: tiny bitwidth, small workload.
    let (bits, rows, n_ops) = if smoke { (5, 16, 200) } else { (8, 16, 1500) };
    let threads = ThreadPool::default_parallelism();
    let dir = std::env::temp_dir().join(format!(
        "openacm_store_warm_bench_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    println!(
        "store warm-vs-cold: {rows}x{bits} sweep, {n_ops} ops, {threads} threads{}",
        if smoke { " [smoke]" } else { "" }
    );

    let mut json = BenchJson::new("store_warm");

    // Cold: every iteration starts from an empty store (the wipe is part
    // of the measured loop but negligible next to the sweep itself).
    let cold = bench(
        &format!("dse sweep {rows}x{bits} (cold store)"),
        0,
        if smoke { 2 } else { 3 },
        || {
            let _ = std::fs::remove_dir_all(&dir);
            let store = DesignPointStore::open(&dir).expect("open store");
            black_box(sweep_configs_cached(rows, bits, n_ops, threads, Some(&store)));
        },
    );
    json.case(&cold);

    // Warm: the store is populated (by the last cold iteration); each
    // iteration re-opens it — index rescan + record reads, no simulation.
    let warm = bench(
        &format!("dse sweep {rows}x{bits} (warm store)"),
        1,
        if smoke { 5 } else { 10 },
        || {
            let store = DesignPointStore::open(&dir).expect("open store");
            black_box(sweep_configs_cached(rows, bits, n_ops, threads, Some(&store)));
        },
    );
    json.case(&warm);

    let speedup = cold.mean_ns / warm.mean_ns;
    println!("→ warm-cache speedup over cold sweep: {speedup:.1}x");
    json.ratio("warm_over_cold", speedup);

    // Sanity: the warm run must actually have been served from the store.
    let store = DesignPointStore::open(&dir).expect("open store");
    let before = store.stats();
    let _ = black_box(sweep_configs_cached(rows, bits, n_ops, threads, Some(&store)));
    let s = store.stats().since(&before);
    println!(
        "→ verification pass: {} hits / {} misses ({:.0}% served from store)",
        s.hits,
        s.misses,
        s.hit_rate() * 100.0
    );
    assert!(
        s.hit_rate() >= 0.9,
        "warm sweep only {:.0}% cached",
        s.hit_rate() * 100.0
    );

    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
