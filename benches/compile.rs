//! Cold-vs-warm accuracy-budgeted compile through the design-point store
//! — the compile pass's headline numbers: a repeated compile must be
//! served from memoized measurements at a wide margin, and the emitted
//! plan must beat the all-exact baseline's energy within budget.
//!
//! ```text
//! cargo bench --bench compile               # full candidate space
//! OPENACM_SMOKE=1 cargo bench --bench compile   # CI smoke (2 fc layers)
//! ```
//!
//! Writes `BENCH_compile.json` (per-case ns/iter, warm_over_cold, and the
//! plan-vs-exact energy ratio) for the CI artifact trail.

use openacm::bench::harness::{bench, black_box, BenchJson};
use openacm::compile::search::{compile_budgeted, CalibrationSet, CompileOptions};
use openacm::nn::model::QuantCnn;
use openacm::store::DesignPointStore;
use openacm::util::threadpool::ThreadPool;

fn main() {
    let smoke_env = std::env::var("OPENACM_SMOKE")
        .map(|v| !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false"))
        .unwrap_or(false);
    let smoke = smoke_env || std::env::args().any(|a| a == "--smoke");
    let mut opts = if smoke {
        CompileOptions::smoke(0.005)
    } else {
        CompileOptions::new(0.005)
    };
    opts.threads = ThreadPool::default_parallelism();
    if !smoke {
        opts.calib_n = 128;
        opts.ppa_ops = 300;
    }
    let model = QuantCnn::random(opts.seed);
    let calib = CalibrationSet::synthetic(&model, opts.calib_n, opts.seed, opts.threads);
    let dir = std::env::temp_dir().join(format!("openacm_compile_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    println!(
        "compile cold-vs-warm: budget {:.2}%, {} calibration images, {} threads{}",
        opts.budget_drop * 100.0,
        calib.n,
        opts.threads,
        if smoke { " [smoke]" } else { "" }
    );

    let mut json = BenchJson::new("compile");

    // Cold: every iteration starts from an empty store.
    let cold = bench("budgeted compile (cold store)", 0, 2, || {
        let _ = std::fs::remove_dir_all(&dir);
        let store = DesignPointStore::open(&dir).expect("open store");
        black_box(compile_budgeted(&model, &calib, &opts, Some(&store)));
    });
    json.case(&cold);

    // Warm: the store holds every measurement from the last cold run.
    let warm = bench("budgeted compile (warm store)", 1, if smoke { 5 } else { 3 }, || {
        let store = DesignPointStore::open(&dir).expect("open store");
        black_box(compile_budgeted(&model, &calib, &opts, Some(&store)));
    });
    json.case(&warm);

    let speedup = cold.mean_ns / warm.mean_ns;
    println!("→ warm-store speedup over cold compile: {speedup:.1}x");
    json.ratio("warm_over_cold", speedup);

    // Verification pass: the warm compile must really be store-served and
    // the plan must beat all-exact energy within the budget.
    let store = DesignPointStore::open(&dir).expect("open store");
    let before = store.stats();
    let plan = compile_budgeted(&model, &calib, &opts, Some(&store));
    let s = store.stats().since(&before);
    println!(
        "→ verification pass: {} hits / {} misses ({:.0}% served from store)",
        s.hits,
        s.misses,
        s.hit_rate() * 100.0
    );
    assert!(
        s.hit_rate() >= 0.9,
        "warm compile only {:.0}% cached",
        s.hit_rate() * 100.0
    );
    assert!(
        plan.drop_vs_exact() <= opts.budget_drop + 1e-9,
        "plan drop {} exceeds budget {}",
        plan.drop_vs_exact(),
        opts.budget_drop
    );
    println!(
        "→ plan [{}]: drop {:.2}%, energy {:.1}% of exact",
        plan.assignment_label(),
        plan.drop_vs_exact() * 100.0,
        (1.0 - plan.energy_saving()) * 100.0
    );
    json.ratio(
        "plan_energy_over_exact",
        plan.plan_energy_per_image_j / plan.exact_energy_per_image_j,
    );

    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
