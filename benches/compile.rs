//! Cold / incremental / warm accuracy-budgeted compile through the
//! design-point store — the compile pass's headline numbers:
//!
//! * **cold** runs the historical full-forward evaluator on an empty
//!   store (every probe pays a whole calibration forward);
//! * **incremental** runs the suffix-replay evaluator on an empty store
//!   (prefix checkpoints + sparse delta replay — same measurements, same
//!   plan bytes, a fraction of the GEMM MACs);
//! * **warm** re-compiles against the populated store (served from
//!   memoized measurements at a wide margin).
//!
//! ```text
//! cargo bench --bench compile               # full candidate space
//! OPENACM_SMOKE=1 cargo bench --bench compile   # CI smoke (2 fc layers)
//! ```
//!
//! Writes `BENCH_compile.json` (per-case ns/iter, warm/incremental
//! speedups, the replayed-MAC counters of the sensitivity phase, and the
//! plan-vs-exact energy ratio) for the CI artifact trail. Asserts:
//! the incremental path replays strictly fewer MACs than cold, the
//! sensitivity-profiling MAC reduction is ≥ 3×, and the incremental and
//! cold compiles emit byte-identical `.acmplan` artifacts.

use openacm::bench::harness::{bench, black_box, BenchJson};
use openacm::compile::search::{compile_budgeted, CalibrationSet, CompileOptions, Compiler};
use openacm::nn::model::QuantCnn;
use openacm::store::DesignPointStore;
use openacm::util::threadpool::ThreadPool;

fn main() {
    let smoke_env = std::env::var("OPENACM_SMOKE")
        .map(|v| !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false"))
        .unwrap_or(false);
    let smoke = smoke_env || std::env::args().any(|a| a == "--smoke");
    let mut opts = if smoke {
        CompileOptions::smoke(0.005)
    } else {
        CompileOptions::new(0.005)
    };
    opts.threads = ThreadPool::default_parallelism();
    if !smoke {
        opts.calib_n = 128;
        opts.ppa_ops = 300;
    }
    let cold_opts = CompileOptions {
        incremental: false,
        ..opts.clone()
    };
    let model = QuantCnn::random(opts.seed);
    let calib = CalibrationSet::synthetic(&model, opts.calib_n, opts.seed, opts.threads);
    let base = std::env::temp_dir().join(format!("openacm_compile_bench_{}", std::process::id()));
    let cold_dir = base.join("cold");
    let inc_dir = base.join("incremental");
    let _ = std::fs::remove_dir_all(&base);
    println!(
        "compile cold-vs-incremental-vs-warm: budget {:.2}%, {} calibration images, {} threads{}",
        opts.budget_drop * 100.0,
        calib.n,
        opts.threads,
        if smoke { " [smoke]" } else { "" }
    );

    let mut json = BenchJson::new("compile");

    // Cold: full-forward evaluator, every iteration from an empty store.
    let cold = bench("budgeted compile (cold, full forwards)", 0, 2, || {
        let _ = std::fs::remove_dir_all(&cold_dir);
        let store = DesignPointStore::open(&cold_dir).expect("open store");
        black_box(compile_budgeted(&model, &calib, &cold_opts, Some(&store)));
    });
    json.case(&cold);

    // Incremental: suffix-replay evaluator, every iteration from an
    // empty store — same measurements, only the replay work differs.
    let incremental = bench("budgeted compile (incremental, cold store)", 0, 2, || {
        let _ = std::fs::remove_dir_all(&inc_dir);
        let store = DesignPointStore::open(&inc_dir).expect("open store");
        black_box(compile_budgeted(&model, &calib, &opts, Some(&store)));
    });
    json.case(&incremental);

    // Warm: the store holds every measurement from the last run.
    let warm = bench(
        "budgeted compile (warm store)",
        1,
        if smoke { 5 } else { 3 },
        || {
            let store = DesignPointStore::open(&inc_dir).expect("open store");
            black_box(compile_budgeted(&model, &calib, &opts, Some(&store)));
        },
    );
    json.case(&warm);

    let warm_speedup = cold.mean_ns / warm.mean_ns;
    let inc_speedup = cold.mean_ns / incremental.mean_ns;
    println!("→ warm-store speedup over cold compile: {warm_speedup:.1}x");
    println!("→ incremental wall-clock speedup over cold compile: {inc_speedup:.1}x");
    json.ratio("warm_over_cold", warm_speedup);
    json.ratio("incremental_over_cold", inc_speedup);

    // Replayed-MAC accounting of the sensitivity phase (baseline + every
    // solo probe), measured on a fresh incremental engine with no store:
    // `full_macs` is exactly what the cold evaluator executes for the
    // same measurements, `replayed_macs` what the incremental one did.
    let probe = Compiler::new(&model, &calib, opts.clone(), None);
    let exact_top1 = probe.measured_top1(&[0; 4]);
    black_box(probe.sensitivity(exact_top1));
    let stats = probe.stats();
    println!(
        "→ sensitivity profiling: {} replayed vs {} cold-equivalent GEMM MACs \
         ({:.2}x fewer; {} as sparse deltas, {} free probes)",
        stats.replayed_macs,
        stats.full_macs,
        stats.mac_reduction(),
        stats.delta_macs,
        stats.free_probes,
    );
    json.ratio("sensitivity_mac_reduction", stats.mac_reduction());
    json.counter("sensitivity_cold_macs", stats.full_macs as f64);
    json.counter("sensitivity_incremental_macs", stats.replayed_macs as f64);
    json.counter("sensitivity_delta_macs", stats.delta_macs as f64);
    assert!(
        stats.replayed_macs < stats.full_macs,
        "incremental sensitivity must replay strictly fewer MACs than cold \
         ({} vs {})",
        stats.replayed_macs,
        stats.full_macs
    );
    assert!(
        stats.mac_reduction() >= 3.0,
        "sensitivity-profiling MAC reduction below target: {:.2}x < 3x",
        stats.mac_reduction()
    );

    // A/B equivalence: the two evaluators' plans must serialize to
    // identical bytes (each store is warm in its own mode by now, and a
    // warm replay is bit-identical by the store round-trip guarantee).
    let cold_store = DesignPointStore::open(&cold_dir).expect("open store");
    let inc_store = DesignPointStore::open(&inc_dir).expect("open store");
    let plan_cold = compile_budgeted(&model, &calib, &cold_opts, Some(&cold_store));
    let before = inc_store.stats();
    let plan = compile_budgeted(&model, &calib, &opts, Some(&inc_store));
    let s = inc_store.stats().since(&before);
    assert_eq!(plan, plan_cold, "incremental and cold plans must match");
    let pa = base.join("plan_incremental.acmplan");
    let pb = base.join("plan_cold.acmplan");
    plan.save(&pa).expect("save plan");
    plan_cold.save(&pb).expect("save plan");
    assert_eq!(
        std::fs::read(&pa).expect("read plan"),
        std::fs::read(&pb).expect("read plan"),
        "incremental and cold .acmplan artifacts must be byte-identical"
    );
    println!("→ A/B check: incremental and cold .acmplan artifacts byte-identical");

    // Verification pass: the warm compile must really be store-served and
    // the plan must beat all-exact energy within the budget.
    println!(
        "→ verification pass: {} hits / {} misses ({:.0}% served from store)",
        s.hits,
        s.misses,
        s.hit_rate() * 100.0
    );
    assert!(
        s.hit_rate() >= 0.9,
        "warm compile only {:.0}% cached",
        s.hit_rate() * 100.0
    );
    assert!(
        plan.drop_vs_exact() <= opts.budget_drop + 1e-9,
        "plan drop {} exceeds budget {}",
        plan.drop_vs_exact(),
        opts.budget_drop
    );
    println!(
        "→ plan [{}]: drop {:.2}%, energy {:.1}% of exact",
        plan.assignment_label(),
        plan.drop_vs_exact() * 100.0,
        (1.0 - plan.energy_saving()) * 100.0
    );
    json.ratio(
        "plan_energy_over_exact",
        plan.plan_energy_per_image_j / plan.exact_energy_per_image_j,
    );

    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
    let _ = std::fs::remove_dir_all(&base);
}
