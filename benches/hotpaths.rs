//! Micro-benchmarks of the compiler's hot paths — the §Perf targets in
//! EXPERIMENTS.md. Each one prints mean/p50/p99 so before/after deltas of
//! optimization work are directly comparable, and the whole run is written
//! to `BENCH_hotpaths.json` (per-case ns/iter + speedup ratios) so the
//! perf trajectory is tracked across PRs.
//!
//! ```text
//! cargo bench --bench hotpaths                  # full size
//! OPENACM_SMOKE=1 cargo bench --bench hotpaths  # CI smoke
//! ```
//!
//! The `scalar planes`/`wide planes` columns pin the SIMD plane-group
//! widening of the bit-parallel engine (`util::simd`, DESIGN.md §"SIMD
//! kernels"): identical results at every width, speedup tracked as
//! `wide_planes_over_scalar_planes`.

use openacm::bench::harness::{bench, black_box, BenchJson};
use openacm::config::spec::{CompressorKind, MultFamily};
use openacm::mult::behavioral::int8_lut;
use openacm::mult::{error_metrics, pptree};
use openacm::nn::model::QuantCnn;
use openacm::sim::activity::{activity_bitparallel, activity_parallel, mult_workload_vectors};
use openacm::sim::event::EventSim;
use openacm::sim::BitParallelSim;
use openacm::util::rng::Pcg32;
use openacm::util::threadpool::ThreadPool;

fn main() {
    let smoke_env = std::env::var("OPENACM_SMOKE")
        .map(|v| !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false"))
        .unwrap_or(false);
    let smoke = smoke_env || std::env::args().any(|a| a == "--smoke");
    // Smoke mode trims warmups/iters only — every case still runs once so
    // the JSON keeps the full column set (CI uploads it per dispatch arm).
    let (w, iters) = if smoke { (0, 2) } else { (1, 20) };
    let simd_level = openacm::util::simd::detect();
    println!(
        "hotpaths: SIMD level {} ({} plane words){}",
        simd_level.name(),
        simd_level.plane_words(),
        if smoke { " [smoke]" } else { "" }
    );

    let mut json = BenchJson::new("hotpaths");
    // 0. The headline: exhaustive INT8 characterization (all 65,536 input
    // vectors, full error metrics) — scalar event-driven engine vs the
    // bit-parallel engine, identical results by construction
    // (rust/tests/sim_equivalence.rs proves bit-identical outputs+toggles).
    let nl8 = pptree::build_approx42(8, CompressorKind::Yang1, 8);
    let fam8 = MultFamily::default_approx(8);
    let scalar = bench("exhaustive int8 char (scalar event sim)", 0, iters.min(3), || {
        let mut sim = EventSim::new(&nl8);
        black_box(error_metrics::exhaustive_sim(&mut sim, 8));
    });
    json.case(&scalar);
    let boolvec = bench(
        "exhaustive int8 char (bit-parallel, bool-vec API)",
        w,
        iters.min(10),
        || {
            let mut sim = BitParallelSim::new(&nl8);
            black_box(error_metrics::exhaustive_sim(&mut sim, 8));
        },
    );
    json.case(&boolvec);
    // Packed sweep at a pinned one-word plane group (the scalar-dispatch
    // oracle) vs the detected SIMD width — same numbers out of both
    // (rust/tests/sim_equivalence.rs), only the wall clock moves.
    let packed = bench("exhaustive int8 char (packed, scalar planes)", w, iters, || {
        black_box(error_metrics::exhaustive_netlist_words(&fam8, 8, 1, 1));
    });
    json.case(&packed);
    let wide = bench(
        &format!(
            "exhaustive int8 char (packed, {} planes x{})",
            simd_level.name(),
            simd_level.plane_words()
        ),
        w,
        iters,
        || {
            black_box(error_metrics::exhaustive_netlist(&fam8, 8, 1));
        },
    );
    json.case(&wide);
    println!(
        "→ bit-parallel speedup over scalar: {:.1}x (single-threaded, scalar planes)",
        scalar.mean_ns / packed.mean_ns
    );
    json.ratio("bitparallel_packed_over_scalar", scalar.mean_ns / packed.mean_ns);
    println!(
        "→ wide-plane ({}) speedup over scalar planes: {:.2}x",
        simd_level.name(),
        packed.mean_ns / wide.mean_ns
    );
    json.ratio("wide_planes_over_scalar_planes", packed.mean_ns / wide.mean_ns);
    let threads = ThreadPool::default_parallelism();
    let mt = bench(
        &format!("exhaustive int8 char (packed, {threads} threads)"),
        w,
        iters,
        || {
            black_box(error_metrics::exhaustive_netlist(&fam8, 8, threads));
        },
    );
    json.case(&mt);
    println!(
        "→ combined speedup over scalar: {:.1}x",
        scalar.mean_ns / mt.mean_ns
    );
    json.ratio("combined_over_scalar", scalar.mean_ns / mt.mean_ns);
    // 1. Netlist generation (the compiler front end).
    let r = bench("build_exact(32) netlist", w, iters, || {
        black_box(pptree::build_exact(32));
    });
    json.case(&r);
    let r = bench("build_logour(32) netlist", w, iters, || {
        black_box(openacm::mult::logarithmic::build_logour(32));
    });
    json.case(&r);

    // 2. Bit-parallel activity extraction (the Table II power hot path).
    let nl = pptree::build_exact(16);
    let mut rng = Pcg32::new(1);
    let pairs: Vec<(u64, u64)> = (0..4096)
        .map(|_| (rng.next_u64() & 0xFFFF, rng.next_u64() & 0xFFFF))
        .collect();
    let vectors = mult_workload_vectors(16, &pairs);
    let r = bench("activity_bitparallel(16b mult, 4096 vecs)", w, iters, || {
        black_box(activity_bitparallel(&nl, &vectors));
    });
    println!(
        "→ {:.1} M gate-evals/s",
        r.throughput((nl.gates().len() * vectors.len()) as f64) / 1e6
    );
    json.case(&r);
    let r = bench(
        &format!("activity_parallel(16b mult, 4096 vecs, {threads}t)"),
        w,
        iters,
        || {
            black_box(activity_parallel(&nl, &vectors, threads));
        },
    );
    json.case(&r);

    // 3. Event-driven simulation (the incremental engine).
    let mut sim = EventSim::new(&nl);
    let r = bench("event_sim(16b mult, 4096 vecs)", w, iters.min(10), || {
        for v in &vectors {
            black_box(sim.step(v));
        }
    });
    println!(
        "→ {:.0} K vectors/s event-driven (wide cones: random operands)",
        r.throughput(vectors.len() as f64) / 1e3
    );
    json.case(&r);

    // 3b. Narrow-cone workload (weight-stationary PE: only the streaming
    // operand's low bits move) — the case the worklist engine targets.
    let narrow: Vec<(u64, u64)> = (0..4096u64).map(|t| (t % 16, 0xBEEF)).collect();
    let narrow_vecs = mult_workload_vectors(16, &narrow);
    let mut sim_n = EventSim::new(&nl);
    let r = bench("event_sim(16b mult, narrow cone)", w, iters.min(10), || {
        for v in &narrow_vecs {
            black_box(sim_n.step(v));
        }
    });
    println!(
        "→ {:.0} K vectors/s event-driven (narrow cones)",
        r.throughput(narrow_vecs.len() as f64) / 1e3
    );
    json.case(&r);

    // 4. 64-lane behavioral multiply (LUT generation hot path).
    let lanes_a: Vec<u64> = (0..64).collect();
    let lanes_b: Vec<u64> = (0..64).map(|i| 255 - i).collect();
    let (mw, mi) = if smoke { (1, 20) } else { (10, 500) };
    let r = bench("soft_multiply_lanes(8b yang1, 64 pairs)", mw, mi, || {
        black_box(pptree::soft_multiply_lanes(
            8,
            8,
            Some(CompressorKind::Yang1),
            &lanes_a,
            &lanes_b,
        ));
    });
    println!("→ {:.1} M mults/s", r.throughput(64.0) / 1e6);
    json.case(&r);

    // 5. int8 LUT generation (python-parity path).
    let r = bench("int8_lut(logour)", w, iters.min(10), || {
        black_box(int8_lut(&MultFamily::LogOur));
    });
    json.case(&r);
    let r = bench("int8_lut(appro42/yang1)", w, iters.min(5), || {
        black_box(int8_lut(&MultFamily::default_approx(8)));
    });
    json.case(&r);

    // 6. Native quantized CNN forward (the no-PJRT fallback).
    let cnn = QuantCnn::random(7);
    let lut = int8_lut(&MultFamily::Exact);
    let img: Vec<u8> = (0..256).map(|i| (i * 7 % 256) as u8).collect();
    let (fw, fi) = if smoke { (1, 10) } else { (5, 100) };
    let r = bench("native QuantCnn::forward (1 image)", fw, fi, || {
        black_box(cnn.forward(&lut, &img));
    });
    println!("→ {:.0} images/s native", r.throughput(1.0));
    json.case(&r);

    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
