//! Micro-benchmarks of the compiler's hot paths — the §Perf targets in
//! EXPERIMENTS.md. Each one prints mean/p50/p99 so before/after deltas of
//! optimization work are directly comparable, and the whole run is written
//! to `BENCH_hotpaths.json` (per-case ns/iter + speedup ratios) so the
//! perf trajectory is tracked across PRs.
//!
//! ```text
//! cargo bench --bench hotpaths
//! ```

use openacm::bench::harness::{bench, black_box, BenchJson};
use openacm::config::spec::{CompressorKind, MultFamily};
use openacm::mult::behavioral::int8_lut;
use openacm::mult::{error_metrics, pptree};
use openacm::nn::model::QuantCnn;
use openacm::sim::activity::{activity_bitparallel, activity_parallel, mult_workload_vectors};
use openacm::sim::event::EventSim;
use openacm::sim::BitParallelSim;
use openacm::util::rng::Pcg32;
use openacm::util::threadpool::ThreadPool;

fn main() {
    let mut json = BenchJson::new("hotpaths");
    // 0. The headline: exhaustive INT8 characterization (all 65,536 input
    // vectors, full error metrics) — scalar event-driven engine vs the
    // 64-lane bit-parallel engine, identical results by construction
    // (rust/tests/sim_equivalence.rs proves bit-identical outputs+toggles).
    let nl8 = pptree::build_approx42(8, CompressorKind::Yang1, 8);
    let fam8 = MultFamily::default_approx(8);
    let scalar = bench("exhaustive int8 char (scalar event sim)", 0, 3, || {
        let mut sim = EventSim::new(&nl8);
        black_box(error_metrics::exhaustive_sim(&mut sim, 8));
    });
    json.case(&scalar);
    let boolvec = bench("exhaustive int8 char (bit-parallel, bool-vec API)", 1, 10, || {
        let mut sim = BitParallelSim::new(&nl8);
        black_box(error_metrics::exhaustive_sim(&mut sim, 8));
    });
    json.case(&boolvec);
    let packed = bench("exhaustive int8 char (bit-parallel, packed)", 1, 20, || {
        black_box(error_metrics::exhaustive_netlist(&fam8, 8, 1));
    });
    json.case(&packed);
    println!(
        "→ bit-parallel speedup over scalar: {:.1}x (single-threaded)",
        scalar.mean_ns / packed.mean_ns
    );
    json.ratio("bitparallel_packed_over_scalar", scalar.mean_ns / packed.mean_ns);
    let threads = ThreadPool::default_parallelism();
    let mt = bench(
        &format!("exhaustive int8 char (packed, {threads} threads)"),
        1,
        20,
        || {
            black_box(error_metrics::exhaustive_netlist(&fam8, 8, threads));
        },
    );
    json.case(&mt);
    println!(
        "→ combined speedup over scalar: {:.1}x",
        scalar.mean_ns / mt.mean_ns
    );
    json.ratio("combined_over_scalar", scalar.mean_ns / mt.mean_ns);
    // 1. Netlist generation (the compiler front end).
    let r = bench("build_exact(32) netlist", 1, 20, || {
        black_box(pptree::build_exact(32));
    });
    json.case(&r);
    let r = bench("build_logour(32) netlist", 1, 20, || {
        black_box(openacm::mult::logarithmic::build_logour(32));
    });
    json.case(&r);

    // 2. Bit-parallel activity extraction (the Table II power hot path).
    let nl = pptree::build_exact(16);
    let mut rng = Pcg32::new(1);
    let pairs: Vec<(u64, u64)> = (0..4096)
        .map(|_| (rng.next_u64() & 0xFFFF, rng.next_u64() & 0xFFFF))
        .collect();
    let vectors = mult_workload_vectors(16, &pairs);
    let r = bench("activity_bitparallel(16b mult, 4096 vecs)", 1, 20, || {
        black_box(activity_bitparallel(&nl, &vectors));
    });
    println!(
        "→ {:.1} M gate-evals/s",
        r.throughput((nl.gates().len() * vectors.len()) as f64) / 1e6
    );
    json.case(&r);
    let r = bench(
        &format!("activity_parallel(16b mult, 4096 vecs, {threads}t)"),
        1,
        20,
        || {
            black_box(activity_parallel(&nl, &vectors, threads));
        },
    );
    json.case(&r);

    // 3. Event-driven simulation (the incremental engine).
    let mut sim = EventSim::new(&nl);
    let r = bench("event_sim(16b mult, 4096 vecs)", 1, 10, || {
        for v in &vectors {
            black_box(sim.step(v));
        }
    });
    println!(
        "→ {:.0} K vectors/s event-driven (wide cones: random operands)",
        r.throughput(vectors.len() as f64) / 1e3
    );
    json.case(&r);

    // 3b. Narrow-cone workload (weight-stationary PE: only the streaming
    // operand's low bits move) — the case the worklist engine targets.
    let narrow: Vec<(u64, u64)> = (0..4096u64).map(|t| (t % 16, 0xBEEF)).collect();
    let narrow_vecs = mult_workload_vectors(16, &narrow);
    let mut sim_n = EventSim::new(&nl);
    let r = bench("event_sim(16b mult, narrow cone)", 1, 10, || {
        for v in &narrow_vecs {
            black_box(sim_n.step(v));
        }
    });
    println!(
        "→ {:.0} K vectors/s event-driven (narrow cones)",
        r.throughput(narrow_vecs.len() as f64) / 1e3
    );
    json.case(&r);

    // 4. 64-lane behavioral multiply (LUT generation hot path).
    let lanes_a: Vec<u64> = (0..64).collect();
    let lanes_b: Vec<u64> = (0..64).map(|i| 255 - i).collect();
    let r = bench("soft_multiply_lanes(8b yang1, 64 pairs)", 10, 500, || {
        black_box(pptree::soft_multiply_lanes(
            8,
            8,
            Some(CompressorKind::Yang1),
            &lanes_a,
            &lanes_b,
        ));
    });
    println!("→ {:.1} M mults/s", r.throughput(64.0) / 1e6);
    json.case(&r);

    // 5. int8 LUT generation (python-parity path).
    let r = bench("int8_lut(logour)", 1, 10, || {
        black_box(int8_lut(&MultFamily::LogOur));
    });
    json.case(&r);
    let r = bench("int8_lut(appro42/yang1)", 1, 5, || {
        black_box(int8_lut(&MultFamily::default_approx(8)));
    });
    json.case(&r);

    // 6. Native quantized CNN forward (the no-PJRT fallback).
    let cnn = QuantCnn::random(7);
    let lut = int8_lut(&MultFamily::Exact);
    let img: Vec<u8> = (0..256).map(|i| (i * 7 % 256) as u8).collect();
    let r = bench("native QuantCnn::forward (1 image)", 5, 100, || {
        black_box(cnn.forward(&lut, &img));
    });
    println!("→ {:.0} images/s native", r.throughput(1.0));
    json.case(&r);

    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
