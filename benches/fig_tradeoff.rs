//! The paper's headline figure (derived): the accuracy-energy trade-off —
//! "energy savings of up to 64% with negligible loss in application
//! accuracy". Sweeps the DSE candidate set at 16×8 and 64×32, prints the
//! Pareto frontier as a text series (NMED vs % of exact energy), and
//! checks the headline numbers.
//!
//! ```text
//! cargo bench --bench fig_tradeoff
//! ```

use openacm::bench::harness::{bench, black_box, sci, Table};
use openacm::config::spec::MultFamily;
use openacm::dse::{pareto_front, sweep_configs};
use openacm::util::threadpool::ThreadPool;

fn main() {
    let threads = ThreadPool::default_parallelism();
    for (rows, bits, ops) in [(16usize, 8usize, 1500usize), (64, 32, 400)] {
        eprintln!("sweeping {rows}x{bits}...");
        let points = sweep_configs(rows, bits, ops, threads);
        let front = pareto_front(&points);
        let mut t = Table::new(
            &format!("accuracy-energy frontier @ {rows}x{bits}"),
            &["Design", "NMED", "Energy vs exact"],
        );
        for p in &front {
            t.row(&[
                p.label.clone(),
                if p.nmed == 0.0 {
                    "exact".into()
                } else {
                    sci(p.nmed)
                },
                format!("{:.0}%", p.energy_ratio * 100.0),
            ]);
        }
        t.print();
        // Headline: the best approximate design's saving at this size.
        let best_saving = points
            .iter()
            .filter(|p| p.nmed > 0.0 && p.nmed < 5e-2)
            .map(|p| 1.0 - p.energy_ratio)
            .fold(0.0f64, f64::max);
        println!(
            "max energy saving with NMED < 5e-2: {:.0}% (paper headline: up to 64% at 64x32)\n",
            best_saving * 100.0
        );
    }

    // Log-our specifically (the headline family) at 64x32.
    let points = sweep_configs(64, 32, 400, threads);
    let lo = points
        .iter()
        .find(|p| matches!(p.family, MultFamily::LogOur))
        .unwrap();
    println!(
        "Log-our @ 64x32: {:.0}% of exact energy (paper: ~36%, i.e. 64% saving)",
        lo.energy_ratio * 100.0
    );

    bench("dse::sweep_configs(16x8, 300 ops)", 0, 3, || {
        black_box(sweep_configs(16, 8, 300, threads));
    });
}
