//! Regenerates **Table III**: PSNR of the approximate multipliers on image
//! blending (8-bit unsigned) and Sobel edge detection (16-bit signed),
//! against the exact-multiplier baseline; times the image pipeline.
//!
//! ```text
//! cargo bench --bench table3_psnr
//! ```

use openacm::apps::cli::{blending_rows, edge_rows, render_table3};
use openacm::apps::{blend, images};
use openacm::bench::harness::{bench, black_box};
use openacm::config::spec::MultFamily;
use openacm::mult::behavioral::uint8_lut;

fn main() {
    let n = 256;
    let mut rows = blending_rows(n);
    rows.extend(edge_rows(n));
    render_table3(&rows).print();
    println!(
        "\npaper Table III reference:\n\
         blending  Lake&Mandril 67.19/32.01/26.08, Jetplane&Boat 70.93/37.17/22.10, Cameraman&Lake 69.81/43.22/24.82\n\
         edge det. Boat 66.21/46.43/38.77, Cameraman 67.55/45.61/38.37, Jetplane 66.20/44.13/39.07\n\
         (columns: Appro4-2 / Log-our / LM [24], dB)\n\
         NOTE: our Appro4-2 lands ~50 dB in blending (reconstructed yang1 cell has\n\
         higher MED than the published one) and the Appro4-2/Log-our order flips in\n\
         edge detection (squaring favours Log-our) — see EXPERIMENTS.md.\n"
    );

    // --- hot path: LUT-based blending (the serving-side image op) ---
    let a = images::lake(n);
    let b = images::mandril(n);
    let lut = uint8_lut(&MultFamily::LogOur);
    bench(&format!("blend_lut({n}x{n})"), 3, 50, || {
        black_box(blend::blend_lut(&a, &b, &lut));
    });
    bench(&format!("blend_behavioral({n}x{n}, logour)"), 1, 10, || {
        black_box(blend::blend(&a, &b, &MultFamily::LogOur));
    });
}
