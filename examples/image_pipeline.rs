//! Image-processing pipeline (the paper's §V-B workloads): blend two
//! images and edge-detect a third through every multiplier family,
//! reporting PSNR against the exact baseline — Table III in miniature,
//! plus per-operation energy from the PPA engine so the accuracy-energy
//! trade-off is visible on a real workload.
//!
//! ```text
//! cargo run --release --example image_pipeline [--size 256]
//! ```

use anyhow::Result;

use openacm::apps::{blend, edge, images, psnr_db};
use openacm::bench::harness::{sci, Table};
use openacm::config::spec::{MacroSpec, MultFamily};
use openacm::ppa::report::analyze_macro;
use openacm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(false, &[])?;
    let n = args.usize_or("size", 256)?;

    let lake = images::lake(n);
    let mandril = images::mandril(n);
    let cameraman = images::cameraman(n);

    let families = vec![
        ("Exact", MultFamily::Exact),
        ("Appro4-2", MultFamily::default_approx(8)),
        ("Log-our", MultFamily::LogOur),
        ("LM [24]", MultFamily::Mitchell),
    ];

    let blend_ref = blend::blend(&lake, &mandril, &MultFamily::Exact);
    let edge_ref = edge::edge_detect(&cameraman, &MultFamily::Exact);

    let mut t = Table::new(
        &format!("image pipeline on {n}x{n} images"),
        &["Multiplier", "Blend PSNR (dB)", "Edge PSNR (dB)", "Energy/op (J)", "vs exact"],
    );
    let exact_energy = analyze_macro(
        &MacroSpec::new("e", 16, 8, MultFamily::Exact),
        1000,
        42,
    )
    .energy_per_op_j;
    for (label, fam) in families {
        let b = blend::blend(&lake, &mandril, &fam);
        let e = {
            // edge detection runs the 16-bit signed datapath
            let fam16 = match &fam {
                MultFamily::Approx42 { .. } => MultFamily::default_approx(16),
                other => other.clone(),
            };
            edge::edge_detect(&cameraman, &fam16)
        };
        let energy = analyze_macro(&MacroSpec::new("m", 16, 8, fam.clone()), 1000, 42)
            .energy_per_op_j;
        let fmt_db = |v: f64| {
            if v.is_infinite() {
                "inf".to_string()
            } else {
                format!("{v:.2}")
            }
        };
        t.row(&[
            label.to_string(),
            fmt_db(psnr_db(&blend_ref, &b)),
            fmt_db(psnr_db(&edge_ref, &e)),
            sci(energy),
            format!("{:.0}%", energy / exact_energy * 100.0),
        ]);
    }
    t.print();
    println!("\n(>40 dB = visually identical, <30 dB = visible degradation)");
    Ok(())
}
