//! Accuracy-budget sweep through the compile pass: compile the same
//! model under a 0%, 0.5% and 2% top-1 drop budget and print each
//! resulting per-layer multiplier assignment with its estimated energy
//! saving vs the all-exact plan — the paper's "bridge application error
//! tolerance to hardware automation" loop, end to end.
//!
//! All three compiles share one design-point store, so the sensitivity
//! profile and every overlapping assignment measurement is paid for once
//! (the budget sweep is mostly store-warm after the first compile) — and
//! each engine borrows the calibration set instead of materializing its
//! own view of it, so sweeping more budget points costs no extra memory.
//! Fresh measurements run through the incremental suffix-replay evaluator
//! (`--no-incremental` falls back to full forwards; plans are
//! byte-identical either way).
//!
//! ```text
//! cargo run --release --example compile_budget -- [--calib 256] [--seed N]
//!     [--rows 16] [--smoke] [--no-cache] [--store DIR] [--no-incremental]
//! ```

use anyhow::Result;

use openacm::bench::harness::{sci, Table};
use openacm::compile::cli::print_plan;
use openacm::compile::search::{compile_budgeted, CalibrationSet, CompileOptions};
use openacm::nn::model::QuantCnn;
use openacm::util::cli::Args;
use openacm::util::threadpool::ThreadPool;

fn main() -> Result<()> {
    let args = Args::from_env(false, &["no-cache", "smoke", "no-incremental"])?;
    let smoke = args.flag("smoke");
    let budgets_pct = [0.0f64, 0.5, 2.0];
    let threads = args.usize_or("threads", ThreadPool::default_parallelism())?;
    let store = openacm::store::cli::store_from_args(&args)?;

    let mut base = if smoke {
        CompileOptions::smoke(0.0)
    } else {
        CompileOptions::new(0.0)
    };
    base.rows = args.usize_or("rows", base.rows)?;
    base.calib_n = args.usize_or("calib", base.calib_n)?;
    base.seed = args.u64_or("seed", base.seed)?;
    base.threads = threads;
    base.incremental = !args.flag("no-incremental");

    let model = QuantCnn::random(base.seed);
    let calib = CalibrationSet::synthetic(&model, base.calib_n, base.seed, threads);
    eprintln!(
        "budget sweep over {:?}% on {} calibration images{}...",
        budgets_pct,
        calib.n,
        if smoke { " [smoke]" } else { "" }
    );

    let mut summary = Table::new(
        "accuracy budget → heterogeneous assignment",
        &["Budget", "conv1", "conv2", "fc1", "fc2", "Drop", "Energy saving"],
    );
    for &pct in &budgets_pct {
        let opts = CompileOptions {
            budget_drop: pct / 100.0,
            ..base.clone()
        };
        let mut plan = compile_budgeted(&model, &calib, &opts, store.as_ref());
        plan.name = format!("sweep_b{pct}");

        print_plan(&plan);
        println!(
            "  measured top-1 {:.4} vs exact {:.4} (drop {:.2}%), energy/image {} J vs {} J\n",
            plan.plan_top1,
            plan.exact_top1,
            plan.drop_vs_exact() * 100.0,
            sci(plan.plan_energy_per_image_j),
            sci(plan.exact_energy_per_image_j)
        );
        summary.row(&[
            format!("{pct}%"),
            plan.layers[0].family.name(),
            plan.layers[1].family.name(),
            plan.layers[2].family.name(),
            plan.layers[3].family.name(),
            format!("{:.2}%", plan.drop_vs_exact() * 100.0),
            format!("{:.1}%", plan.energy_saving() * 100.0),
        ]);
    }
    summary.print();
    if let Some(store) = &store {
        println!("\nstore {}: {}", store.root().display(), store.stats().summary());
    }
    Ok(())
}
