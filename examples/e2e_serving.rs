//! End-to-end driver (DESIGN.md §6): the full three-layer stack on a real
//! small workload.
//!
//! * L1/L2 — the Pallas LUT-matmul kernel inside the AOT-compiled JAX
//!   quantized-CNN graph (built by `make artifacts`);
//! * L3 — the Rust coordinator: per-variant dynamic batchers executing
//!   through a pluggable backend, with Python nowhere on the request path.
//!
//! Backends (`--backend native|pjrt|auto`, default `auto`):
//!
//! * `pjrt` — the AOT graph through PJRT (needs `make artifacts`);
//! * `native` — the batched, cache-blocked Rust LUT-GEMM path. With
//!   artifacts present it serves the real weights/LUTs/dataset; with no
//!   artifacts at all it runs a fully synthetic workload (deterministic
//!   random model, behavioral LUTs, labels = exact-variant predictions),
//!   so the complete serving stack — admission → batcher → execute →
//!   respond — is exercised end to end with zero build-path outputs.
//!
//! Submits a few hundred classification requests against all four
//! multiplier variants concurrently, then reports per-variant Top-1,
//! latency percentiles, throughput, and the per-inference *energy*
//! estimate from the PPA engine — i.e. the paper's headline
//! accuracy-vs-energy statement measured end to end. Results are recorded
//! in EXPERIMENTS.md.
//!
//! ```text
//! cargo run --release --example e2e_serving -- --backend native --requests 400
//! make artifacts && cargo run --release --example e2e_serving -- --requests 400
//! ```

use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use openacm::bench::harness::{sci, Table};
use openacm::config::spec::{MacroSpec, MultFamily};
use openacm::coordinator::batcher::BatchPolicy;
use openacm::coordinator::server::{Delivery, InferenceServer, Request};
use openacm::ppa::report::analyze_macro;
use openacm::runtime::backend::select_backend;
use openacm::runtime::{ArtifactStore, BackendChoice, BackendFactory};
use openacm::util::cli::Args;
use openacm::util::threadpool::ThreadPool;

fn main() -> Result<()> {
    let args = Args::from_env(false, &[])?;
    let n_requests = args.usize_or("requests", 400)?;
    let choice = BackendChoice::parse(args.str_or("backend", "auto"))?;
    let threads = ThreadPool::default_parallelism();
    let dir = ArtifactStore::default_dir();
    let (factory, workload) = select_backend(choice, &dir, 32, threads, 42)?;

    println!(
        "backend {}: {} images, {} variants, batch capacity {}",
        factory.backend_name(),
        workload.n_images,
        factory.variants().len(),
        factory.max_batch()
    );

    let server = InferenceServer::start_with_backend(
        factory,
        BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            // This driver measures accuracy/energy, not SLO conformance —
            // give requests a deadline they will never hit.
            slo: Duration::from_secs(60),
            ..BatchPolicy::default()
        },
        4096,
    )?;
    let variants = server.variants();

    // Fire all requests asynchronously, round-robin across variants.
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let idx = i % workload.n_images;
        let variant = variants[i % variants.len()].clone();
        let (tx, rx) = channel();
        server.submit(Request::to_variant(
            workload.image(idx).to_vec(),
            variant.clone(),
            tx,
        ))?;
        pending.push((idx, variant, rx));
    }
    let mut correct: BTreeMap<String, (usize, usize)> = BTreeMap::new();
    for (idx, variant, rx) in pending {
        let e = correct.entry(variant).or_insert((0, 0));
        e.1 += 1;
        if let Delivery::Ok(resp) = rx.recv()? {
            if resp.predicted == workload.labels[idx] {
                e.0 += 1;
            }
        }
    }
    let wall = t0.elapsed();

    // Per-variant energy from the PPA engine (the 16×8 macro).
    let energy: BTreeMap<&str, f64> = [
        ("exact", MultFamily::Exact),
        ("appro42", MultFamily::default_approx(8)),
        ("logour", MultFamily::LogOur),
        ("lm", MultFamily::Mitchell),
    ]
    .into_iter()
    .map(|(name, fam)| {
        let ppa = analyze_macro(&MacroSpec::new(name, 16, 8, fam), 1000, 42);
        (name, ppa.energy_per_op_j)
    })
    .collect();
    let exact_energy = energy["exact"];

    let mut t = Table::new(
        "end-to-end serving: accuracy vs energy per multiplier variant",
        &["Variant", "Top-1", "Requests", "Energy/op (J)", "vs exact"],
    );
    for (variant, (ok, total)) in &correct {
        let e = energy.get(variant.as_str()).copied().unwrap_or(f64::NAN);
        t.row(&[
            variant.clone(),
            format!("{:.3}", *ok as f64 / *total as f64),
            total.to_string(),
            sci(e),
            format!("{:.0}%", e / exact_energy * 100.0),
        ]);
    }
    t.print();

    let snap = server.metrics.snapshot();
    println!(
        "\n{} requests in {:.2}s — {:.0} req/s, latency p50 {:.2} ms p90 {:.2} ms p99 {:.2} ms, mean batch {:.1}",
        snap.completed,
        wall.as_secs_f64(),
        snap.completed as f64 / wall.as_secs_f64(),
        snap.p50_ms,
        snap.p90_ms,
        snap.p99_ms,
        snap.mean_batch
    );
    server.shutdown();
    Ok(())
}
