use openacm::bench::harness::{bench, black_box};
use openacm::sram::cell6t::Cell6T;
fn main() {
    let cell = Cell6T::default();
    bench("characterize_read", 5, 200, || { black_box(cell.characterize_read()); });
    bench("characterize_full", 2, 50, || { black_box(cell.characterize()); });
}
