//! Quickstart: compile one approximate DCiM macro end to end.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Builds the paper's default 16×8 macro with the tunable Appro4-2
//! multiplier, runs the full compiler (netlists → Verilog → LEF/LIB →
//! OpenROAD scripts → PPA signoff substitute), then exercises the
//! behavioral PE on a dot-product workload and prints the multiplier's
//! error statistics — the whole public API surface in ~60 lines.

use anyhow::Result;

use openacm::config::spec::{MacroSpec, MultFamily};
use openacm::flow::generate_all;
use openacm::mult::error_metrics;
use openacm::pe::ProcessingElement;

fn main() -> Result<()> {
    // 1. Describe the macro: 16 rows × 8-bit words, Appro4-2 multiplier
    //    (yang1 compressors on PP columns #0..#7 — Fig 2's red box).
    let spec = MacroSpec::new("dcim16x8", 16, 8, MultFamily::default_approx(8));
    spec.validate()?;

    // 2. Run the compiler: everything lands in build/quickstart.
    let artifacts = generate_all(&spec, std::path::Path::new("build/quickstart"))?;
    println!("compiler artifacts ({}):", artifacts.dir.display());
    for f in &artifacts.files {
        println!("  {}", f.file_name().unwrap().to_string_lossy());
    }
    println!("\n{}", artifacts.ppa_summary);

    // 3. Error statistics of the selected multiplier (Table IV metrics).
    let report = error_metrics::exhaustive(&spec.mult.family, 8);
    println!(
        "multiplier error: NMED {:.3e}  MRED {:.3e}  ER {:.3}  WCE {}",
        report.nmed, report.mred, report.error_rate, report.wce
    );

    // 4. Drive the behavioral PE: load weights, stream a dot product.
    let mut pe = ProcessingElement::new(&spec)?;
    let weights: Vec<u64> = (1..=16).map(|i| (i * 13) % 256).collect();
    pe.load_weights(&weights)?;
    let inputs: Vec<u64> = (1..=16).map(|i| (i * 7) % 256).collect();
    let approx = pe.dot(&inputs)?;
    let exact: u128 = inputs
        .iter()
        .zip(&weights)
        .map(|(&x, &w)| (x * w) as u128)
        .sum();
    println!(
        "PE dot product: approx {approx} vs exact {exact} ({:+.3}% error, {} SRAM reads)",
        (approx as f64 - exact as f64) / exact as f64 * 100.0,
        pe.sram_reads()
    );
    pe.finish();
    Ok(())
}
