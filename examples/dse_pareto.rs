//! Design-space exploration: sweep the full multiplier candidate set
//! (exact, adder-tree, both log families, 6 compressor designs × 4 column
//! budgets) at a given macro geometry and print the accuracy-energy Pareto
//! frontier plus accuracy-constrained selections — the compiler knob the
//! paper's §VI roadmap calls for, implemented.
//!
//! Runs through the persistent design-point store by default, so a
//! repeated exploration is served from disk (bit-identical results); pass
//! `--no-cache` to force recomputation. Hit/miss counts print at the end.
//!
//! ```text
//! cargo run --release --example dse_pareto -- [--rows 16] [--word-bits 8]
//!     [--no-cache] [--store DIR]
//! ```

use anyhow::Result;

use openacm::bench::harness::{sci, Table};
use openacm::dse::{pareto_front, sweep_configs_cached};
use openacm::dse::pareto::select_under_constraint;
use openacm::util::cli::Args;
use openacm::util::threadpool::ThreadPool;

fn main() -> Result<()> {
    let args = Args::from_env(false, &["no-cache"])?;
    let rows = args.usize_or("rows", 16)?;
    let bits = args.usize_or("word-bits", 8)?;
    let threads = args.usize_or("threads", ThreadPool::default_parallelism())?;
    let store = openacm::store::cli::store_from_args(&args)?;

    eprintln!("sweeping candidates at {rows}x{bits} with {threads} threads...");
    let points = sweep_configs_cached(rows, bits, 1500, threads, store.as_ref());
    println!("evaluated {} design points", points.len());

    let front = pareto_front(&points);
    let mut t = Table::new(
        "accuracy-energy Pareto frontier",
        &["Design", "NMED", "Energy/op (J)", "vs exact", "Logic area (um2)"],
    );
    for p in &front {
        t.row(&[
            p.label.clone(),
            if p.nmed == 0.0 {
                "exact".into()
            } else {
                sci(p.nmed)
            },
            sci(p.energy_per_op_j),
            format!("{:.0}%", p.energy_ratio * 100.0),
            format!("{:.0}", p.logic_area_um2),
        ]);
    }
    t.print();

    println!("\naccuracy-constrained selections:");
    for budget in [1e-5, 1e-4, 1e-3, 1e-2, 1e-1] {
        match select_under_constraint(&points, budget) {
            Some(best) => println!(
                "  NMED <= {budget:.0e}: {:24} {:.0}% of exact energy",
                best.label,
                best.energy_ratio * 100.0
            ),
            None => println!("  NMED <= {budget:.0e}: (only exact qualifies)"),
        }
    }

    match &store {
        Some(store) => println!(
            "\ndesign-point store {}: {}",
            store.root().display(),
            store.stats().summary()
        ),
        None => println!("\ndesign-point store disabled (--no-cache)"),
    }
    Ok(())
}
