//! Variation-aware SRAM yield analysis (paper §V-C): one full MC-vs-MNIS
//! comparison on a trimmed array, with the failure-boundary diagnostics
//! (β, mean-shift point) that the paper's OpenYield integration exposes.
//!
//! ```text
//! cargo run --release --example yield_analysis -- [--size 32] [--fom 0.15]
//! ```

use anyhow::Result;

use openacm::util::cli::Args;
use openacm::util::threadpool::ThreadPool;
use openacm::yield_analysis::{problem::SramYieldProblem, run_mc, run_mnis};

fn main() -> Result<()> {
    let args = Args::from_env(false, &[])?;
    let rows = args.usize_or("size", 32)?;
    let fom = args.f64_or("fom", 0.15)?;
    let seed = args.u64_or("seed", 2026)?;
    let threads = args.usize_or("threads", ThreadPool::default_parallelism())?;

    let problem = SramYieldProblem::table5(rows);
    println!(
        "trimmed {rows}x2 array: SNM crit {:.3} V, access crit {:.3} ns, sigma x{:.2}",
        problem.snm_crit, problem.taccess_crit_ns, problem.sigma_scale
    );

    println!("\nplain Monte-Carlo (FoM target {fom}):");
    let mc = run_mc(&problem, fom, 150_000, seed, threads);
    println!(
        "  Pf {:.3e}  FoM {:.3}  {} sims  ({} failures)",
        mc.pf, mc.fom, mc.sims, mc.failures
    );

    println!("\nMNIS importance sampling:");
    let is = run_mnis(&problem, fom, 40_000, seed);
    println!(
        "  Pf {:.3e}  FoM {:.3}  {} sims  ({} in the norm-min search)",
        is.pf, is.fom, is.sims, is.search_sims
    );
    println!(
        "  min-norm failure at beta = {:.2} sigma, shift = {:?}",
        is.beta,
        is.shift
            .iter()
            .map(|v| (v * 100.0).round() / 100.0)
            .collect::<Vec<_>>()
    );
    println!(
        "\nspeedup: {:.1}x fewer simulator calls for the same FoM target",
        mc.sims as f64 / is.sims.max(1) as f64
    );

    // Automated transistor sizing (paper §III-D): smallest 6T sizing that
    // meets the guard-banded stability/writeability/current targets.
    println!("\nautomated transistor sizing (3-sigma guard band):");
    let sized = openacm::sram::sizing::optimize(&openacm::sram::SizingTargets::default())?;
    println!(
        "  W_PD {:.2}  W_PU {:.2}  W_PG {:.2}  (total width {:.1} Wmin, {} simulator calls)",
        sized.wpd, sized.wpu, sized.wpg, sized.total_width, sized.evals
    );
    println!(
        "  guard-banded: read SNM {:.3} V, write margin {:.3} V, read current {:.1} uA",
        sized.read_snm,
        sized.write_margin,
        sized.read_current * 1e6
    );
    Ok(())
}
